"""Public bignum facade: the ONE front door for the paper's arithmetic.

Every operation here takes and returns **32-bit limb arrays** (uint32,
little-endian, limb axis last, leading axes are batch lanes -- the
GMP-facing radix of ``core/limbs.py``) and follows one kwarg
convention:

  * ``method=``  picks a multiply/divide pipeline implementation
    ("auto" dispatches by size and batch; see core/mul.select_method,
    core/div.select_div_method),
  * ``backend=`` picks a modular-arithmetic device backend (None
    auto-dispatches; see core/modular.select_modexp_backend).

This replaces the per-module scatter of entry points (mul_limbs32 /
divmod_limbs32 / mod_exp-on-digit-arrays / rsa.sign...) for callers
that just want arithmetic: the serving engine
(serve/bignum_engine.py), the examples, and downstream users all go
through here.  The digit-radix internals stay importable for kernels
and tests.

Configuration
-------------
``configure(...)`` is the supported way to override dispatch:

    repro.api.configure(mul_method="ntt")          # process-wide
    with repro.api.configure(modexp_backend="jnp"):  # scoped
        ...

The legacy ``REPRO_MUL_BACKEND`` / ``REPRO_DIV_BACKEND`` /
``REPRO_MODEXP_BACKEND`` / ``REPRO_AUTOTUNE`` environment variables
keep working as deprecated aliases (one DeprecationWarning per process
each) at lower precedence; see repro/config.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import config as _config
from repro.core import div as _div
from repro.core import limbs as _L
from repro.core import modular as _M
from repro.core import mul as _mul
from repro.core import rsa as _rsa

U32 = jnp.uint32
DIGIT_BITS = 16

# re-exported names that already have the right shape/contract
mod_setup = _M.mod_setup
exp_bits_msb = _M.exp_bits_msb
generate_key = _rsa.generate_key
digest_int = _rsa.digest_int
RSAKey = _rsa.RSAKey

__all__ = [
    "mul", "divmod", "mod_exp", "rsa_sign", "rsa_verify", "rsa_decrypt",
    "to_decimal", "configure", "cache_stats", "metrics", "dispatch_report",
    "to_limbs", "from_limbs",
    "mod_setup", "exp_bits_msb", "generate_key", "digest_int", "RSAKey",
]


# ---------------------------------------------------------------------------
# host-side conversions
# ---------------------------------------------------------------------------

def to_limbs(values, nbits: int) -> np.ndarray:
    """Python int(s) -> uint32 limb array sized for ``nbits``.

    A single int gives (m,); a sequence gives (len, m) with
    m = ceil(nbits / 32).  Values must be >= 0 and < 2**nbits (the
    declared width, not the rounded-up limb width).  Bad inputs raise
    ValueError naming the offending argument here at the facade, not as
    shape errors deep in the limb layer."""
    import operator

    if not isinstance(nbits, int) or isinstance(nbits, bool) or nbits <= 0:
        raise ValueError(
            f"to_limbs: nbits must be a positive int, got {nbits!r}")
    m = -(-nbits // 32)
    single = isinstance(values, int) and not isinstance(values, bool)
    if single:
        seq = [values]
    else:
        try:
            seq = list(values)
        except TypeError:
            raise ValueError(
                f"to_limbs: values must be an int or a sequence of ints, "
                f"got {type(values).__name__}") from None
    checked = []
    for i, v in enumerate(seq):
        where = "values" if single else f"values[{i}]"
        if isinstance(v, bool):
            raise ValueError(f"to_limbs: {where} must be an int, got a bool")
        try:
            v = operator.index(v)
        except TypeError:
            raise ValueError(
                f"to_limbs: {where} must be an int, got "
                f"{type(v).__name__}") from None
        if v < 0:
            raise ValueError(f"to_limbs: {where} must be >= 0, got {v}")
        if v.bit_length() > nbits:
            raise ValueError(
                f"to_limbs: {where} needs {v.bit_length()} bits but "
                f"nbits={nbits}")
        checked.append(v)
    if single:
        return _L.int_to_limbs(checked[0], m, 32)
    return _L.ints_to_batch(checked, m, 32)


def from_limbs(arr) -> "int | list[int]":
    """uint32 limb array -> python int ((m,)) or list of ints ((..., m),
    flattened over the leading axes in C order)."""
    a = np.asarray(arr, np.uint32)
    if a.ndim == 1:
        return _L.limbs_to_int(a, 32)
    return _L.batch_to_ints(a.reshape(-1, a.shape[-1]), 32)


def _digits_from_limbs(x, m_digits: int) -> jax.Array:
    """(..., ma) 32-bit limbs -> (..., m_digits) 16-bit digits (pad or
    truncate; truncated digits must be zero -- values < the modulus)."""
    d = _mul.split_digits(jnp.asarray(x, U32), DIGIT_BITS)
    n = d.shape[-1]
    if n < m_digits:
        pad = [(0, 0)] * (d.ndim - 1) + [(0, m_digits - n)]
        return jnp.pad(d, pad)
    return d[..., :m_digits]


def _limbs_from_digits(d, ma: int) -> jax.Array:
    return _mul.join_digits(d, DIGIT_BITS, ma)


def _limb_width(ctx) -> int:
    return -(-(ctx.m * DIGIT_BITS) // 32)


# ---------------------------------------------------------------------------
# arithmetic front doors
# ---------------------------------------------------------------------------

def mul(a, b, *, method: str = "auto") -> jax.Array:
    """Full product: (..., m) x (..., m) uint32 limbs -> (..., 2m).

    ``method``: "auto" (size/batch dispatch) or one of
    core/mul.MUL_METHODS.  Under ``configure(selfcheck=...)`` the result
    is verified against the mod-p residue product identity (one fold per
    operand, see repro/resilience/selfcheck.py)."""
    out = _mul.mul_limbs32(a, b, method=method)
    from repro.resilience import selfcheck as _sc
    _sc.check_mul(a, b, out)
    return out


def divmod(a, b, *, method: str = "auto",
           b_const: int | None = None):  # noqa: A001 - facade name
    """Exact floor (quotient, remainder): (..., ma) // (..., mb) uint32
    limbs -> ((..., ma), (..., mb)).  ``method``: "auto" or one of
    core/div.DIV_METHODS.  ``b_const`` declares the divisor a host-known
    constant (b must hold that value in every lane): the reciprocal
    path's fixed-operand multiplies then reuse cached forward NTTs
    (see cache_stats()["operand"]).  Under ``configure(selfcheck=...)``
    the result is verified against the residue identity
    res(q)*res(b) + res(r) == res(a)."""
    q, r = _div.divmod_limbs32(a, b, method=method, b_const=b_const)
    from repro.resilience import selfcheck as _sc
    _sc.check_divmod(a, b, q, r)
    return q, r


def to_decimal(x, n_dec: int) -> jax.Array:
    """(..., m) uint32 limbs -> (..., n_dec) base-10 digits, most
    significant first (on-device divide-and-conquer base conversion)."""
    return _div.to_decimal_limbs32(x, n_dec)


def mod_exp(base, exponent, modulus, *, backend: str | None = None,
            window: int | None = None, nbits: int | None = None
            ) -> jax.Array:
    """base ** exponent mod modulus on (..., m) uint32 limb arrays.

    ``modulus``: python int, or a prebuilt context from ``mod_setup``
    (build once per modulus when serving -- setup is host-side work).
    ``exponent``: python int (converted host-side), or a (..., nbits)
    MSB-first bit array for per-lane exponents.  ``base`` lanes must be
    < modulus.  ``backend=None`` auto-dispatches (fused Pallas ladder
    for kernel-sized batches); ``nbits`` pads the modulus width (shape
    bucketing -- requests of different widths share one trace)."""
    ctx = _M.mod_setup(modulus, nbits) if isinstance(modulus, int) \
        else modulus
    eb = _M.exp_bits_msb(exponent) if isinstance(exponent, int) \
        else exponent
    d = _digits_from_limbs(base, ctx.m)
    out = _M.mod_exp(d, jnp.asarray(eb), ctx, backend=backend,
                     window=window)
    out = _limbs_from_digits(out, _limb_width(ctx))
    from repro.resilience import selfcheck as _sc
    if _sc.enabled() and isinstance(exponent, int) \
            and not _sc._any_tracer(base, out):
        # modexp has no residue identity (see selfcheck.py): the check
        # is an exact host pow() witness per lane -- the documented cost
        # of verifying an op with no cheap public inverse
        mw = np.shape(base)[-1]
        b_np = np.asarray(base, np.uint32).reshape(-1, mw)
        o_np = np.asarray(out, np.uint32)
        o2 = o_np.reshape(-1, o_np.shape[-1])
        bad = sum(
            1 for i in range(o2.shape[0])
            if _L.limbs_to_int(o2[i], 32) != pow(
                _L.limbs_to_int(b_np[i % b_np.shape[0]], 32),
                exponent, ctx.n))
        if bad:
            _sc.report("mod_exp", bad, "host pow witness")
    return out


# ---------------------------------------------------------------------------
# RSA front doors
# ---------------------------------------------------------------------------

def rsa_sign(msg, key: "_rsa.RSAKey", *, backend: str | None = None
             ) -> jax.Array:
    """s = m ** d mod n on (..., ma) uint32 limbs (ma = ceil(bits/32))."""
    ctx = key.ctx
    d = _digits_from_limbs(msg, ctx.m)
    return _limbs_from_digits(_rsa.sign(d, key, backend=backend),
                              _limb_width(ctx))


def rsa_verify(sig, key: "_rsa.RSAKey", *, backend: str | None = None
               ) -> jax.Array:
    """m = s ** e mod n on (..., ma) uint32 limbs."""
    ctx = key.ctx
    d = _digits_from_limbs(sig, ctx.m)
    return _limbs_from_digits(_rsa.verify(d, key, backend=backend),
                              _limb_width(ctx))


def rsa_decrypt(cipher, key: "_rsa.RSAKey", *, backend: str | None = None,
                crt: bool = True) -> jax.Array:
    """m = c ** d mod n on (..., ma) uint32 limbs.  ``crt=True`` (needs
    a key with known p, q) runs the two half-size CRT modexps; False
    falls back to the full-width ladder (== rsa_sign)."""
    ctx = key.ctx
    d = _digits_from_limbs(cipher, ctx.m)
    if crt:
        out = _rsa.decrypt_crt(d, key, backend=backend)[..., :ctx.m]
    else:
        out = _rsa.sign(d, key, backend=backend)
    return _limbs_from_digits(out, _limb_width(ctx))


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

_UNSET = object()


class _ConfigureContext:
    """Returned by configure(): a no-op unless used as a context
    manager, in which case __exit__ restores the previous overrides."""

    def __init__(self, prev: dict):
        self._prev = prev

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        _config.set_overrides(self._prev)
        return False


def configure(*, mul_method=_UNSET, div_method=_UNSET,
              modexp_backend=_UNSET, autotune=_UNSET,
              ntt_cache_entries=_UNSET, observability=_UNSET,
              on_retrace=_UNSET, selfcheck=_UNSET,
              kernel_fallback=_UNSET) -> _ConfigureContext:
    """Override dispatch decisions, process-wide or scoped.

    Keyword-only; omitted knobs are left untouched, ``None`` clears an
    override (back to env alias, then heuristics):

      * ``mul_method``      one of core/mul.MUL_METHODS,
      * ``div_method``      one of core/div.DIV_METHODS,
      * ``modexp_backend``  one of core/modular.BACKENDS,
      * ``autotune``        bool -- enable the kernel tile sweep,
      * ``ntt_cache_entries``  int >= 0 -- LRU capacity of the
        prepared-operand NTT cache (kernels/ntt_mul); 0 disables the
        prepared path entirely (the A/B switch benchmarks use), None
        restores the default (see kernels/ntt_mul/ops.
        DEFAULT_CACHE_ENTRIES),
      * ``observability``   bool -- master switch for repro.obs
        (dispatch-trace events, spans, engine metric ticking); off by
        default so instrumentation costs nothing on hot paths,
      * ``on_retrace``      "ignore" / "warn" / "raise" -- the
        retrace-alarm policy when an armed zero-retrace contract sees
        a fresh jit trace (default "warn"; the ``retraces_total``
        counter ticks under every policy, see repro/obs/retrace.py),
      * ``selfcheck``       None/False (off, the default) or "warn" /
        "raise" -- verify mul/divmod results against mod-p residue
        identities and mod_exp / engine crypto results against host
        witnesses; failures tick ``selfcheck_failures_total`` under
        every policy (see repro/resilience/selfcheck.py),
      * ``kernel_fallback`` bool -- True/None (default) degrades a
        failing Pallas tier through jnp to the host reference so every
        request still answers; False is strict mode (the first kernel
        failure propagates -- what CI uses to catch regressions that
        silent degradation would hide, see repro/resilience/guard.py).

    Returns a context manager: ``with configure(...):`` restores the
    previous values on exit; a bare call applies them permanently.
    Replaces the deprecated REPRO_* env vars (still honored, one
    DeprecationWarning each, at lower precedence)."""
    updates: dict = {}
    if mul_method is not _UNSET:
        if mul_method is not None and mul_method not in _mul.MUL_METHODS:
            raise ValueError(
                f"unknown multiply method {mul_method!r}; choose from "
                f"{_mul.MUL_METHODS}")
        updates["mul_method"] = mul_method
    if div_method is not _UNSET:
        if div_method is not None and div_method not in _div.DIV_METHODS:
            raise ValueError(
                f"unknown division method {div_method!r}; choose from "
                f"{_div.DIV_METHODS}")
        updates["div_method"] = div_method
    if modexp_backend is not _UNSET:
        if modexp_backend is not None \
                and modexp_backend not in _M.BACKENDS:
            raise ValueError(
                f"unknown backend {modexp_backend!r}; choose from "
                f"{_M.BACKENDS}")
        updates["modexp_backend"] = modexp_backend
    if autotune is not _UNSET:
        if autotune is not None and not isinstance(autotune, bool):
            raise ValueError(
                f"autotune must be a bool or None, got {autotune!r}")
        updates["autotune"] = autotune
    if ntt_cache_entries is not _UNSET:
        if ntt_cache_entries is not None and (
                not isinstance(ntt_cache_entries, int)
                or isinstance(ntt_cache_entries, bool)
                or ntt_cache_entries < 0):
            raise ValueError(
                f"ntt_cache_entries must be an int >= 0 or None, got "
                f"{ntt_cache_entries!r}")
        updates["ntt_cache_entries"] = ntt_cache_entries
    if observability is not _UNSET:
        if observability is not None and not isinstance(observability, bool):
            raise ValueError(
                f"observability must be a bool or None, got "
                f"{observability!r}")
        updates["observability"] = observability
    if on_retrace is not _UNSET:
        from repro.obs import retrace as _rt
        if on_retrace is not None and on_retrace not in _rt.POLICIES:
            raise ValueError(
                f"unknown on_retrace policy {on_retrace!r}; choose from "
                f"{_rt.POLICIES}")
        updates["on_retrace"] = on_retrace
    if selfcheck is not _UNSET:
        from repro.resilience import selfcheck as _sc
        if selfcheck not in (None, False) and selfcheck not in _sc.POLICIES:
            raise ValueError(
                f"unknown selfcheck policy {selfcheck!r}; choose from "
                f"{_sc.POLICIES} (or None/False to disable)")
        updates["selfcheck"] = selfcheck
    if kernel_fallback is not _UNSET:
        if kernel_fallback is not None \
                and not isinstance(kernel_fallback, bool):
            raise ValueError(
                f"kernel_fallback must be a bool or None, got "
                f"{kernel_fallback!r}")
        updates["kernel_fallback"] = kernel_fallback
    return _ConfigureContext(_config.set_overrides(updates))


def cache_stats() -> dict:
    """Hit/miss/size counters for every process-level arithmetic cache:

      * ``twiddle``  -- the lru_cache of per-(prime, N) NTT twiddle
        tables (kernels/ntt_mul.twiddle_tables),
      * ``operand``  -- the prepared-operand NTT cache (forward
        transforms of host-known constants, LRU-bounded by
        ``configure(ntt_cache_entries=...)``),
      * ``autotune`` -- the kernel tile-sweep cache (hits/misses only
        tick while ``configure(autotune=True)``),
      * ``ctx``      -- the memoized host-side modulus contexts
        (core/modular.mont_setup / barrett_setup lru_caches; the
        ``_as_barrett`` promotion path answers from the barrett_setup
        cache, so its reuse shows up there).

    Returns plain dicts of ints -- cheap to call, safe to log from
    serving loops; the ops knob for verifying that repeat-operand work
    is actually being reused (a cold ``operand`` cache under a
    repeat-multiply-by-constant workload means b_const isn't being
    threaded; churning ``ctx`` misses under a finite key set means
    contexts are being rebuilt per call)."""
    from repro.kernels.common import autotune as _at
    from repro.kernels.ntt_mul import ops as _nops

    def _lru(info):
        return {"hits": info.hits, "misses": info.misses,
                "entries": info.currsize, "capacity": info.maxsize}

    return {
        "twiddle": _lru(_nops.twiddle_tables.cache_info()),
        "operand": _nops.operand_cache_stats(),
        "autotune": _at.cache_stats(),
        "ctx": {
            "mont_setup": _lru(_M.mont_setup.cache_info()),
            "barrett_setup": _lru(_M.barrett_setup.cache_info()),
        },
    }


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def metrics() -> dict:
    """Snapshot of the process metrics registry (repro/obs/metrics.py)
    plus the arithmetic cache counters.

    ``{"counters": {name: {labels: value}}, "gauges": ...,
    "histograms": {name: {labels: {count/sum/min/max/p50/p95/p99}}},
    "caches": cache_stats(), "breaker": ...}`` -- JSON-serializable, so
    serving loops and CI can dump it as an artifact.  Dispatch/span/
    latency series only populate while ``configure(observability=True)``;
    the ``retraces_total`` counter and the resilience series
    (``fallback_total`` / ``shed_total`` / ``deadline_miss_total`` /
    ``breaker_state`` / ``selfcheck_failures_total``) tick regardless
    (runtime contracts, not debug detail -- see repro/obs/retrace.py and
    repro/resilience/).  ``breaker`` is the circuit-breaker snapshot:
    every quarantined (op, shape-bucket, backend) key with its state and
    time-to-retry, plus any forced-open patterns."""
    from repro.obs import metrics as _om
    from repro.resilience.breaker import BREAKER as _breaker

    snap = _om.REGISTRY.snapshot()
    snap["caches"] = cache_stats()
    snap["breaker"] = _breaker.snapshot()
    return snap


def dispatch_report() -> list:
    """Aggregated dispatch-trace rows ({dispatcher, nbits, batch,
    choice, rule, detail, count}) from the bounded event buffer --
    which backend each tier chooser picked and WHICH threshold fired.
    Empty unless ``configure(observability=True)`` was on while the
    workload dispatched (decisions are recorded at trace time, so a
    jit-cached replay emits nothing new).  Render with
    ``repro.obs.format_report()``."""
    from repro.obs import trace as _ot

    return _ot.report()
