"""Process-local circuit breaker for kernel backends.

A breaker key is ``(op, shape-bucket, backend)`` -- the same granularity
the guarded executor (resilience/guard.py) dispatches at: one failing
shape/backend combination must not poison other shapes of the same
kernel, and one failing kernel must not poison the jnp tiers.

State machine (per key):

  * ``closed``    -- healthy; calls flow through.
  * ``open``      -- the backend failed for this key; calls are skipped
    (the guard falls straight to the next tier, ticking
    ``fallback_total{reason="quarantined"}``) until ``cooldown_s``
    elapses.
  * ``half_open`` -- cooldown expired; ONE probe call is allowed
    through.  Success closes the key; failure re-opens it for another
    cooldown.

The breaker opens on the FIRST failure: a Pallas compile / lowering /
VMEM failure is deterministic for a given shape, so retrying it per
request would pay the failed-compile latency on every call.  The timed
half-open probe exists for the transient minority (driver hiccups,
memory pressure from a neighbor).

``force_open(...)`` pins keys open by op/backend pattern regardless of
history -- the benchmark/ops knob for measuring the degraded tier
without manufacturing a real failure (see benchmarks/bench_serve.py).

Transitions mirror into the ``breaker_state`` gauge (0 closed /
1 half_open / 2 open) so the observability surface from PR 8 covers
quarantine decisions; like ``retraces_total``, the gauge is written
even with observability off -- a quarantined kernel is an operational
signal, not a debug detail.

Import-light (stdlib + repro.obs.metrics): the core dispatchers consult
the breaker from inside jit traces.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Tuple

from repro.obs import metrics as _metrics

STATES = ("closed", "half_open", "open")
STATE_VALUES = {name: i for i, name in enumerate(STATES)}

METRIC = "breaker_state"

DEFAULT_COOLDOWN_S = 30.0

BreakerKey = Tuple[str, int, str]


def shape_bucket(nbits: int) -> int:
    """Power-of-two shape bucket >= nbits (floor 32): breaker state is
    per size regime, not per exact width, matching how compile/VMEM
    failures generalize (a 1040-bit overflow will also hit 1024)."""
    b = 32
    while b < nbits:
        b *= 2
    return b


class CircuitBreaker:
    """Keyed breaker; ``clock`` is injectable for deterministic tests."""

    def __init__(self, cooldown_s: float = DEFAULT_COOLDOWN_S,
                 clock=time.monotonic):
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._open_until: Dict[BreakerKey, float] = {}
        self._probing: set = set()
        self._forced: list = []          # (op or None, backend or None)

    @staticmethod
    def key(op: str, nbits: int, backend: str) -> BreakerKey:
        return (op, shape_bucket(nbits), backend)

    # -- state ------------------------------------------------------------

    def _forced_open(self, op: str, backend: str) -> bool:
        return any((fo is None or fo == op) and (fb is None or fb == backend)
                   for fo, fb in self._forced)

    def state(self, op: str, nbits: int, backend: str) -> str:
        with self._lock:
            if self._forced_open(op, backend):
                return "open"
            k = self.key(op, nbits, backend)
            until = self._open_until.get(k)
            if until is None:
                return "closed"
            if k in self._probing or self._clock() >= until:
                return "half_open"
            return "open"

    def allow(self, op: str, nbits: int, backend: str) -> bool:
        """True when a call to this key may proceed.  In ``half_open``
        exactly one caller gets True (the probe); the key stays blocked
        for everyone else until record_success / record_failure."""
        with self._lock:
            if self._forced_open(op, backend):
                return False
            k = self.key(op, nbits, backend)
            until = self._open_until.get(k)
            if until is None:
                return True
            if k in self._probing:
                return False                 # probe in flight
            if self._clock() >= until:
                self._probing.add(k)
                self._set_gauge(k, "half_open")
                return True
            return False

    def record_failure(self, op: str, nbits: int, backend: str) -> None:
        with self._lock:
            k = self.key(op, nbits, backend)
            self._probing.discard(k)
            self._open_until[k] = self._clock() + self.cooldown_s
            self._set_gauge(k, "open")

    def record_success(self, op: str, nbits: int, backend: str) -> None:
        with self._lock:
            k = self.key(op, nbits, backend)
            self._probing.discard(k)
            if k in self._open_until:
                del self._open_until[k]
                self._set_gauge(k, "closed")

    # -- ops knobs --------------------------------------------------------

    def force_open(self, *, op: Optional[str] = None,
                   backend: Optional[str] = None) -> None:
        """Pin every key matching (op, backend) open (None: wildcard)
        until ``clear_forced()`` -- measure the fallback tier on demand."""
        with self._lock:
            self._forced.append((op, backend))

    def clear_forced(self) -> None:
        with self._lock:
            self._forced.clear()

    def reset(self) -> None:
        with self._lock:
            self._open_until.clear()
            self._probing.clear()
            self._forced.clear()

    def snapshot(self) -> dict:
        """{"op/bits/backend": {"state": ..., "retry_in_s": ...}} for
        every non-closed key, plus the active forced patterns."""
        with self._lock:
            now = self._clock()
            out = {}
            for k, until in sorted(self._open_until.items()):
                op, bits, backend = k
                state = ("half_open" if k in self._probing or now >= until
                         else "open")
                out[f"{op}/{bits}/{backend}"] = {
                    "state": state,
                    "retry_in_s": max(0.0, round(until - now, 3)),
                }
            return {"keys": out,
                    "forced": [{"op": fo, "backend": fb}
                               for fo, fb in self._forced]}

    def _set_gauge(self, k: BreakerKey, state: str) -> None:
        op, bits, backend = k
        _metrics.REGISTRY.gauge(
            METRIC, "kernel quarantine state "
                    "(0 closed / 1 half_open / 2 open)").set(
            STATE_VALUES[state], op=op, bits=bits, backend=backend)


BREAKER = CircuitBreaker()
