"""Guarded tiered execution: every Pallas backend entry runs through
here so a compile / VMEM / lowering failure degrades to the next tier
instead of killing the request.

``run(op, nbits, tiers)`` walks an ordered list of (backend, thunk)
tiers -- conventionally ``pallas -> jnp -> reference`` -- and returns
the first success:

  * a tier whose breaker key (op, shape-bucket, backend) is open is
    skipped outright, ticking ``fallback_total{reason="quarantined"}``
    (no failed-compile latency paid per request while quarantined);
  * a tier that raises opens its breaker key, ticks
    ``fallback_total{op,backend,reason}`` with the classified failure,
    and falls through to the next tier;
  * the FINAL tier is the correctness anchor: it is never skipped by
    the breaker and its exceptions propagate (there is nothing left to
    fall back to).

``repro.api.configure(kernel_fallback=False)`` turns fall-through off
(strict mode: the first failure propagates -- CI uses it to catch
regressions that silent degradation would hide); quarantine skipping
still applies, because a forced-open breaker is an explicit operator
decision.

The guard runs at trace time inside jit (core dispatchers call it while
XLA is tracing), which is exactly where Pallas compile and lowering
failures surface; the ``fallback_total`` ticks are therefore per-trace,
not per-call -- matching the dispatch-trace semantics of PR 8, and
matching ``inject.log()`` one-to-one for the chaos gates.  Like
``retraces_total``, the counter ticks even with observability off.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

from repro import config as _config
from repro.obs import metrics as _metrics
from repro.resilience import inject as _inject
from repro.resilience.breaker import BREAKER

METRIC = "fallback_total"

_HELP = "kernel-tier fallbacks by op/backend/reason"


def fallback_enabled() -> bool:
    """configure(kernel_fallback=...): None/True -> degrade through the
    tiers; False -> strict mode (first failure propagates)."""
    value = _config.get_override("kernel_fallback")
    return True if value is None else bool(value)


def classify(exc: BaseException) -> str:
    """Coarse failure-reason label for ``fallback_total`` (stable label
    set: cardinality-bounded, greppable in metrics artifacts)."""
    if isinstance(exc, _inject.InjectedFault):
        return "injected"
    msg = f"{type(exc).__name__}: {exc}".lower()
    if "resource_exhausted" in msg or "resource exhausted" in msg \
            or "out of memory" in msg or "vmem" in msg:
        return "oom"
    if "lower" in msg or "mosaic" in msg or "unsupported" in msg \
            or "not implemented" in msg or "notimplemented" in msg:
        return "lowering"
    if "compil" in msg:
        return "compile"
    return type(exc).__name__


def tick(op: str, backend: str, reason: str, amount: int = 1) -> None:
    """Public tick for callers with their own fallback logic (the
    serving engine's flush degradation / selfcheck repair)."""
    _metrics.REGISTRY.counter(METRIC, _HELP).inc(
        amount, op=op, backend=backend, reason=reason)


def run(op: str, nbits: int, tiers: List[Tuple[str, Callable]]):
    """Execute the first healthy tier; degrade on failure (see module
    docstring).  ``tiers`` is ordered fastest-first; the last entry must
    be infallible-by-construction (jnp composition or host reference)."""
    last_exc: BaseException | None = None
    final = len(tiers) - 1
    for i, (backend, thunk) in enumerate(tiers):
        if i < final and not BREAKER.allow(op, nbits, backend):
            tick(op, backend, "quarantined")
            continue
        try:
            _inject.fire(f"{op}/{backend}")
            out = thunk()
        except Exception as exc:                    # noqa: BLE001
            if i == final:
                raise
            BREAKER.record_failure(op, nbits, backend)
            tick(op, backend, classify(exc))
            last_exc = exc
            if not fallback_enabled():
                raise
            continue
        BREAKER.record_success(op, nbits, backend)
        return out
    # unreachable unless tiers was empty (the final tier either
    # returned or raised)
    raise last_exc if last_exc is not None else ValueError(
        f"guard.run: no tiers given for op {op!r}")
