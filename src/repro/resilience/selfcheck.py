"""Residue self-checking: cheap algebraic verification of arithmetic
results, opt-in via ``repro.api.configure(selfcheck="warn"|"raise")``.

The check folds each lane's inputs and outputs modulo the Fermat prime
p = 2**16 + 1 and tests the identity the operation must satisfy:

  * multiply:  res(a) * res(b)          == res(a*b)   (mod p)
  * divmod:    res(q) * res(b) + res(r) == res(a)     (mod p)

Folding a little-endian 32-bit limb array mod p is ONE vector op:
2**16 == -1 (mod p) makes 2**32 == 1, so each limb contributes
``lo16 - hi16`` and the residue is a plain alternating digit sum --
exactly the digit-fold trick the paper family uses for casting-out
checks, in the radix this repo already stores.  (The issue sketch says
"a 30-bit prime"; on the uint32-only VPU a 30-bit prime would need
64-bit products plus a Montgomery fold per step, so the Fermat prime's
free fold is the engineering choice: a random single-bit corruption
escapes one check with probability 1/p < 2**-16, and the serving
engine's witness checks below close the gap to zero for the crypto
ops.)

Modular exponentiation has NO such residue identity (the quotient of
the reduction is not available, and sound countermeasures like
Blomer-Otto-Seifert's widened modulus change the operand layout), so
the serving engine verifies crypto results per lane with host
witnesses instead -- exact, and cheap where it matters:

  * rsa_sign / rsa_decrypt: the classic RSA fault countermeasure --
    re-encrypt with the PUBLIC exponent (pow(result, e, n), 17 bits
    for e = 65537) and compare with the input;
  * rsa_verify / mod_exp: recompute with python-int pow (rsa_verify's
    public exponent is short; raw mod_exp pays a full host ladder,
    the documented cost of checking an op with no public inverse).

A failed check ticks ``selfcheck_failures_total{op,...}`` (always, like
``retraces_total``) and then applies the policy: "warn" emits a
``SelfCheckWarning``, "raise" raises ``SelfCheckError``.  The engine
additionally REPAIRS failed lanes from the witness (reference tier)
before applying the policy, so served results stay bit-exact either
way -- see serve/bignum_engine.py.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro import config as _config
from repro.obs import metrics as _metrics

P = (1 << 16) + 1                    # Fermat prime F4: 2**16 == -1 (mod P)

POLICIES = ("warn", "raise")

METRIC = "selfcheck_failures_total"
_HELP = "residue/witness self-check failures by op"


class SelfCheckWarning(UserWarning):
    """A self-check mismatch under policy "warn"."""


class SelfCheckError(RuntimeError):
    """A self-check mismatch under policy "raise"."""


def policy():
    """The active selfcheck policy, or None when disabled."""
    value = _config.get_override("selfcheck")
    if value in (None, False):
        return None
    return str(value)


def enabled() -> bool:
    return policy() is not None


# ---------------------------------------------------------------------------
# residue folds
# ---------------------------------------------------------------------------

def fold_int(v: int) -> int:
    return v % P


def fold_limbs(arr) -> np.ndarray:
    """(..., m) uint32 little-endian limbs -> (...,) residues mod P.

    One vectorized pass: limb_i * 2**(32 i) == limb_i (mod P), and each
    limb splits as lo + 2**16 hi == lo - hi.  Sums stay well inside
    int64 for any supported width."""
    a = np.asarray(arr, np.uint32)
    lo = (a & np.uint32(0xFFFF)).astype(np.int64)
    hi = (a >> np.uint32(16)).astype(np.int64)
    return (lo - hi).sum(axis=-1) % P


def _any_tracer(*arrays) -> bool:
    """True when any argument is an abstract jax tracer (the check only
    runs on concrete values; under jit the caller's own program is the
    thing being traced and there is nothing to compare host-side)."""
    import jax

    return any(isinstance(x, jax.core.Tracer) for x in arrays)


def report(op: str, n_bad: int, detail: str, **labels) -> None:
    """Tick the failure counter (always) and apply the policy."""
    _metrics.REGISTRY.counter(METRIC, _HELP).inc(n_bad, op=op, **labels)
    msg = (f"selfcheck: {n_bad} {op} lane(s) failed verification "
           f"({detail})")
    if policy() == "raise":
        raise SelfCheckError(msg)
    warnings.warn(msg, SelfCheckWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# facade-level residue checks (repro.api.mul / repro.api.divmod)
# ---------------------------------------------------------------------------

def check_mul(a, b, out) -> None:
    """Verify res(a)*res(b) == res(out) lane-wise; no-op when disabled
    or while tracing."""
    if not enabled() or _any_tracer(a, b, out):
        return
    ra, rb, ro = fold_limbs(a), fold_limbs(b), fold_limbs(np.asarray(out))
    bad = int(np.count_nonzero((ra * rb) % P != ro))
    if bad:
        report("mul", bad, f"residue product identity mod {P}")


def check_divmod(a, b, q, r) -> None:
    """Verify res(q)*res(b) + res(r) == res(a) lane-wise."""
    if not enabled() or _any_tracer(a, b, q, r):
        return
    ra, rb = fold_limbs(a), fold_limbs(b)
    rq, rr = fold_limbs(np.asarray(q)), fold_limbs(np.asarray(r))
    bad = int(np.count_nonzero((rq * rb + rr) % P != ra))
    if bad:
        report("divmod", bad, f"residue divmod identity mod {P}")


# ---------------------------------------------------------------------------
# witness checks for the crypto ops (serving engine, per real lane)
# ---------------------------------------------------------------------------

def verify_lane(op: str, value: int, result: int, *, modulus=None,
                exponent=None, key=None) -> bool:
    """True when ``result`` is consistent with ``value`` under ``op``
    (python-int witnesses; see module docstring for which check is the
    cheap public-exponent inverse vs a full recompute)."""
    if op == "mod_exp":
        return result == pow(value, exponent, modulus)
    if op == "rsa_sign":
        return pow(result, key.e, key.n) == value % key.n
    if op == "rsa_verify":
        return result == pow(value, key.e, key.n)
    if op == "rsa_decrypt":
        return pow(result, key.e, key.n) == value % key.n
    raise ValueError(f"selfcheck.verify_lane: unknown op {op!r}")


def repair_lane(op: str, value: int, *, modulus=None, exponent=None,
                key=None) -> int:
    """The reference-tier (python-int) recompute of one lane -- what a
    failed lane is replaced with."""
    if op == "mod_exp":
        return pow(value, exponent, modulus)
    if op == "rsa_sign":
        return pow(value % key.n, key.d, key.n)
    if op == "rsa_verify":
        return pow(value, key.e, key.n)
    if op == "rsa_decrypt":
        return pow(value % key.n, key.d, key.n)
    raise ValueError(f"selfcheck.repair_lane: unknown op {op!r}")
