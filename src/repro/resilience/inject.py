"""Deterministic fault injection: the chaos harness's write side.

Every degradation path in the stack (guarded kernel dispatch, engine
flush retry, residue self-checking) is DRIVEN by this module in tests
and CI rather than trusted: ``launch/chaos_bignum.py`` installs specs,
replays a request trace, and compares the resilience counters against
``log()`` -- the realized injections -- exactly.

Determinism model: injections do NOT share one RNG stream (interleaving
would make realized faults depend on unrelated call order).  Each spec
keeps its own per-site fire counter; a spec fires when its counter hits
the ``every`` cadence, capped at ``count`` total fires, and any
randomness inside an event (which lane/limb/bit a corruption flips)
comes from a counter-indexed seeded generator -- same seed + same call
sequence => byte-identical faults and an identical ``log()``.

Spec kinds:

  * ``compile_fail`` / ``flush_error`` -- raise ``InjectedFault`` at a
    matching ``fire()`` site (kernel entries / engine flush),
  * ``latency``     -- sleep ``delay_s`` at a matching ``fire()`` site,
  * ``corrupt``     -- flip one bit of one real lane in a result block
    passed through ``corrupt()`` (the engine calls it on every flush
    output, so an installed spec simulates a device fault downstream of
    a correct kernel -- exactly what residue self-checking must catch).

Sites are matched by substring so one spec can cover a family
(``site="modexp"`` hits "modexp/pallas" and "modexp/barrett_fused").
Everything is a no-op (one truthiness check) when no specs are
installed; stdlib + numpy only, so kernel entry points can call
``fire()`` without import-graph consequences.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import List, Optional

import numpy as np

KINDS = ("compile_fail", "flush_error", "latency", "corrupt")


class InjectedFault(RuntimeError):
    """A deliberately injected failure (never raised outside chaos)."""


@dataclasses.dataclass
class _Spec:
    kind: str
    site: str = ""                  # substring match ("" matches all)
    every: int = 1                  # fire on every N-th matching call
    count: Optional[int] = None     # cap on total fires (None: unlimited)
    delay_s: float = 0.0            # latency kind only
    seed: int = 0
    calls: int = 0
    fires: int = 0


_specs: List[_Spec] = []
_log: List[dict] = []


def install(kind: str, site: str = "", *, every: int = 1,
            count: Optional[int] = None, delay_s: float = 0.0,
            seed: int = 0) -> None:
    """Install one fault spec (see module docstring for kinds)."""
    if kind not in KINDS:
        raise ValueError(f"unknown inject kind {kind!r}; choose from {KINDS}")
    if every < 1:
        raise ValueError(f"inject every must be >= 1, got {every}")
    _specs.append(_Spec(kind=kind, site=site, every=every, count=count,
                        delay_s=delay_s, seed=seed))


def clear() -> None:
    """Remove every spec and the realized-injection log."""
    _specs.clear()
    _log.clear()


def active() -> bool:
    return bool(_specs)


def log() -> List[dict]:
    """Realized injections, in order: the plan the chaos gates compare
    the resilience counters against."""
    return list(_log)


def _due(spec: _Spec) -> bool:
    """Advance the spec's call counter; True when this call fires."""
    spec.calls += 1
    if spec.count is not None and spec.fires >= spec.count:
        return False
    if spec.calls % spec.every:
        return False
    spec.fires += 1
    return True


def fire(site: str) -> None:
    """Chaos hook at an execution site: raises / sleeps per any matching
    non-corrupt spec.  Call sites: kernel op entries ("kernels/<pkg>"),
    the guarded executor ("<op>/<backend>"), and the engine flush loop
    ("serve/flush/<op>")."""
    if not _specs:
        return
    for spec in _specs:
        if spec.kind == "corrupt" or spec.site not in site:
            continue
        if not _due(spec):
            continue
        _log.append({"kind": spec.kind, "site": site, "seq": spec.fires})
        if spec.kind == "latency":
            time.sleep(spec.delay_s)
        else:
            raise InjectedFault(
                f"injected {spec.kind} at {site} (fire #{spec.fires})")


def corrupt(site: str, block: np.ndarray, n_real: int) -> np.ndarray:
    """Chaos hook on a result block: flips one bit of one REAL lane per
    matching due ``corrupt`` spec (lane/limb/bit drawn from a
    counter-indexed seeded generator).  Returns the (possibly copied
    and corrupted) block; identity when nothing fires."""
    if not _specs or n_real < 1:
        return block
    for spec in _specs:
        if spec.kind != "corrupt" or spec.site not in site:
            continue
        if not _due(spec):
            continue
        rng = np.random.default_rng(
            (spec.seed << 20) ^ zlib.crc32(site.encode()) ^ spec.fires)
        lane = int(rng.integers(0, n_real))
        limb = int(rng.integers(0, block.shape[-1]))
        bit = int(rng.integers(0, 32))
        block = np.array(block, copy=True)
        block[lane, limb] ^= np.uint32(1 << bit)
        _log.append({"kind": "corrupt", "site": site, "seq": spec.fires,
                     "lane": lane, "limb": limb, "bit": bit})
    return block
