"""Fault-tolerance layer: circuit breaker, guarded tiered dispatch,
deterministic fault injection, and residue/witness self-checking.

See the module docstrings for the contracts; the serving integration
lives in serve/bignum_engine.py and the chaos driver in
launch/chaos_bignum.py.
"""
from repro.resilience import guard, inject, selfcheck
from repro.resilience.breaker import BREAKER, CircuitBreaker, shape_bucket

__all__ = [
    "BREAKER",
    "CircuitBreaker",
    "guard",
    "inject",
    "selfcheck",
    "shape_bucket",
]
