"""Deterministic, shardable synthetic-LM data pipeline.

Stateless batch generation: batch(step) is a pure function of
(seed, step), so the pipeline is
  * resumable -- restart at step k reproduces the exact stream (no
    iterator state in checkpoints beyond the step counter),
  * shardable -- any host can materialize any row slice independently
    (multi-host: each host generates only its rows),
  * learnable -- tokens follow an affine recurrence x_{t+1} = a*x_t + c
    (mod V) with per-step random starts, so next-token prediction is a
    deterministic map the model can actually learn (the trainer test
    asserts the loss drops).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mult: int = 31         # affine recurrence multiplier
    inc: int = 7


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, rows: slice | None = None) -> dict:
        """Materialize (a row slice of) the batch for `step`."""
        cfg = self.cfg
        rows = rows or slice(0, cfg.global_batch)
        n = rows.stop - rows.start
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, rows.start]))
        x0 = rng.integers(0, cfg.vocab_size, (n, 1), dtype=np.int64)
        toks = [x0]
        for _ in range(cfg.seq_len):
            toks.append((toks[-1] * cfg.mult + cfg.inc) % cfg.vocab_size)
        seq = np.concatenate(toks, axis=1)
        return {
            "tokens": seq[:, : cfg.seq_len].astype(np.int32),
            "targets": seq[:, 1: cfg.seq_len + 1].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
