"""SIMD large-number arithmetic on TPU (jax / Pallas).

The public surface is the ``repro.api`` facade -- ``mul`` / ``divmod``
/ ``mod_exp`` / ``rsa_sign`` / ``rsa_verify`` / ``rsa_decrypt`` /
``to_decimal`` on uint32 limb arrays, plus ``configure`` for dispatch
overrides.  Its names are re-exported here lazily (PEP 562) so that
``import repro`` (and imports of the pure-host submodules like
``repro.configs``) stay light: jax loads only when an api name is
first touched.
"""
from __future__ import annotations

_API_NAMES = (
    "mul", "divmod", "mod_exp", "rsa_sign", "rsa_verify", "rsa_decrypt",
    "to_decimal", "configure", "to_limbs", "from_limbs", "mod_setup",
    "exp_bits_msb", "generate_key", "digest_int", "RSAKey",
    "cache_stats", "metrics", "dispatch_report",
)

__all__ = list(_API_NAMES) + ["api"]


def __getattr__(name: str):
    if name == "api" or name in _API_NAMES:
        # importlib, NOT ``from repro import api``: the fromlist probe
        # re-enters this __getattr__ before the submodule binds.
        import importlib
        _api = importlib.import_module("repro.api")
        return _api if name == "api" else getattr(_api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
