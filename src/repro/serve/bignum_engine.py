"""Request-level continuous batching for large-number crypto ops.

The LM ServeEngine (serve/engine.py) batches token streams; this engine
batches *arithmetic requests*: independent RSA sign / verify / decrypt
and raw mod_exp calls arriving one at a time are aggregated into padded
``slots``-lane batches so the fused windowed ladder runs in its
``MODEXP_DISPATCH.fused_min_batch`` regime instead of at batch 1.

Two mechanisms make an arbitrary request mix serve from a FINITE set of
compiled programs (the retrace economics that motivate the design: a
fresh XLA trace of a 1024-bit ladder costs seconds on this grid, the op
itself milliseconds):

* **Shape bucketing** -- a request's modulus width is quantized up to a
  ``ServeConfig.bucket_bits`` tier (raw mod_exp exponent widths to
  ``exp_bucket_bits``), so arbitrary natural widths collapse onto a few
  padded shapes.  RSA-key ops keep their natural width: the key set is
  finite, so it is already a finite shape set.
* **Per-modulus program cache** -- the Pallas ladder bakes the
  Montgomery constant n0p statically (kernels/dot_modmul/ops.py), so a
  modulus CANNOT be traced data; the jit cache therefore keys on
  ``(op, width-bucket, exp-bucket, modulus)`` and ``warm()``
  pre-compiles the registered modulus/key set before traffic.

Batching policy (continuous): requests queue per bucket key; a bucket
flushes when it reaches ``slots`` lanes (full flush) or when its oldest
request has waited ``max_wait_s`` (deadline flush, padded by repeating
lane 0).  ``replay_trace`` replays a timed arrival trace against the
engine event by event -- virtual arrival clock, real measured service
times, single serial device -- and ``NaiveServer`` / ``replay_naive``
is the one-request-at-a-time natural-shape baseline the benchmarks
compare against.

Fault tolerance (PR 9)
----------------------
The engine assumes failures and bounds them instead of crashing:

* **Admission control / shedding** -- ``submit`` rejects on arrival
  (``req.shed = True``, ``shed_total`` ticks, request completes with no
  result) when the queue exceeds ``ServeConfig.max_queue`` or the
  oldest deadline has slipped more than ``max_wait_s`` past due, so a
  burst degrades to bounded rejections, not unbounded latency.
* **Deadline accounting** -- a request carrying ``sla_s`` that
  completes later than that ticks ``deadline_miss_total{op,bits}``.
* **Retry + degrade** -- a flush that raises is retried up to
  ``max_retries`` (exponential backoff from ``retry_backoff_s``); when
  retries exhaust, the bucket is DEGRADED one backend tier
  (auto/pallas -> jnp -> host reference) and re-run, ticking
  ``fallback_total{op,backend,reason=flush_*}``.  The recompile a
  degrade forces is expected, so it does not trip the retrace alarm.
* **Partial-failure warm()** -- a bucket whose warm-up fails degrades
  the same way instead of failing the whole warm pass; warm is also
  idempotent per bucket (re-warming is a no-op, not a jit-cache leak).
* **Graceful shutdown** -- ``close()`` drains pending queues, then
  marks the engine terminal: submit/warm after close raise a clear
  RuntimeError instead of leaking state.
* **Residue self-checking** -- under ``configure(selfcheck=...)``
  every real lane of every flush is verified against a host witness
  (public-exponent re-encryption for sign/decrypt, pow() recompute
  otherwise -- see repro/resilience/selfcheck.py); a corrupted lane is
  REPAIRED from the witness before results are returned, ticking
  ``selfcheck_failures_total`` and applying the warn/raise policy.

All arithmetic goes through the ``repro.api`` facade; this module never
imports the digit-radix internals.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import api, obs
from repro.configs.dot_bignum import SERVE, ServeConfig, quantize_bits
from repro.obs import metrics as _metrics
from repro.obs import retrace as _retrace
from repro.resilience import guard as _guard
from repro.resilience import inject as _inject
from repro.resilience import selfcheck as _selfcheck

OPS = ("mod_exp", "rsa_sign", "rsa_verify", "rsa_decrypt")

# (op, width bucket bits, exp bucket bits or None, modulus / key.n)
BucketKey = Tuple[str, int, Optional[int], int]


@dataclasses.dataclass
class BignumRequest:
    """One crypto call.  ``value`` is the natural-width uint32 limb
    vector of the operand (mod_exp base, message, signature, or
    ciphertext); ``modulus`` + ``exponent`` (python ints) for op
    "mod_exp", ``key`` for the rsa_* ops.  The engine fills
    ``arrival`` / ``deadline`` / ``completion`` / ``result``."""

    rid: int
    op: str
    value: np.ndarray
    modulus: Optional[int] = None
    exponent: Optional[int] = None
    key: Optional[api.RSAKey] = None
    sla_s: Optional[float] = None       # per-request latency SLA
    arrival: float = 0.0
    deadline: float = 0.0
    completion: Optional[float] = None
    result: Optional[np.ndarray] = None
    shed: bool = False                  # rejected at admission (no result)

    @property
    def latency(self) -> float:
        if self.completion is None:
            raise ValueError(f"request {self.rid} not served yet")
        return self.completion - self.arrival


@dataclasses.dataclass
class EngineStats:
    traces: int = 0           # jit cache misses (python body executions)
    programs: int = 0         # distinct compiled entry points
    served: int = 0
    batches: int = 0
    flush_full: int = 0
    flush_deadline: int = 0
    padded_lanes: int = 0
    shed: int = 0             # requests rejected at admission
    retries: int = 0          # flush attempts repeated after a failure
    degraded: int = 0         # bucket backend-tier demotions
    deadline_misses: int = 0  # requests completing past their sla_s
    selfcheck_failures: int = 0   # lanes caught (and repaired) by selfcheck


class BignumEngine:
    """Continuous-batching server for the ops in ``OPS``.

    The event API is clock-explicit so replays and tests are
    deterministic: callers pass virtual times in, and every method that
    may run device work returns the list of requests it completed
    (empty when it only queued).  ``submit`` flushes on batch-full;
    ``flush_next_due`` serves the earliest expired deadline;
    ``drain_one`` force-flushes when the trace is over."""

    def __init__(self, cfg: Optional[ServeConfig] = None, *,
                 backend: Optional[str] = None):
        self.cfg = cfg or SERVE
        self.backend = backend
        self.stats = EngineStats()
        self._queues: Dict[BucketKey, List[BignumRequest]] = {}
        self._deadlines: Dict[BucketKey, float] = {}
        self._fns: Dict[BucketKey, Callable] = {}
        self._ctxs: Dict[Tuple[int, int], object] = {}
        # the zero-retrace contract arms once warm() completes: any jit
        # body execution after that is an unexpected retrace
        self._warmed = False
        self._warmed_keys: set = set()      # warm() idempotence
        self._degraded: Dict[BucketKey, str] = {}   # bucket -> demoted tier
        self._expect_trace = False          # a degrade's recompile is legit
        self._closed = False

    # -- bucketing --------------------------------------------------------

    def bucket_key(self, req: BignumRequest) -> BucketKey:
        """Quantized jit-cache key for a request (public for tests)."""
        if req.op not in OPS:
            raise ValueError(
                f"unknown serve op {req.op!r}; choose from {OPS}")
        if req.op == "mod_exp":
            if req.modulus is None or req.exponent is None:
                raise ValueError(
                    "mod_exp requests need modulus= and exponent=")
            nbits = quantize_bits(req.modulus.bit_length(),
                                  self.cfg.bucket_bits)
            ebits = quantize_bits(max(1, req.exponent.bit_length()),
                                  self.cfg.exp_bucket_bits)
            return (req.op, nbits, ebits, req.modulus)
        if req.key is None:
            raise ValueError(f"{req.op} requests need key=")
        return (req.op, req.key.bits, None, req.key.n)

    def _ctx(self, modulus: int, nbits: int):
        k = (modulus, nbits)
        if k not in self._ctxs:
            self._ctxs[k] = api.mod_setup(modulus, nbits)
        return self._ctxs[k]

    # -- compiled-program cache -------------------------------------------

    def _fn(self, bkey: BucketKey, sample: BignumRequest) -> Callable:
        if bkey in self._fns:
            return self._fns[bkey]
        op, nbits, _, _ = bkey
        stats = self.stats
        backend = self._degraded.get(bkey, self.backend)
        engine = self
        if op == "mod_exp":
            ctx = self._ctx(sample.modulus, nbits)

            def body(base, exp_bits, _ctx=ctx):
                stats.traces += 1
                engine._on_trace(op, nbits)
                return api.mod_exp(base, exp_bits, _ctx, backend=backend)
        elif op == "rsa_decrypt":
            key, crt = sample.key, sample.key.p != 0

            def body(base, _key=key, _crt=crt):
                stats.traces += 1
                engine._on_trace(op, nbits)
                return api.rsa_decrypt(base, _key, backend=backend,
                                       crt=_crt)
        else:
            f = api.rsa_sign if op == "rsa_sign" else api.rsa_verify
            key = sample.key

            def body(base, _f=f, _key=key):
                stats.traces += 1
                engine._on_trace(op, nbits)
                return _f(base, _key, backend=backend)
        fn = jax.jit(body)
        self._fns[bkey] = fn
        stats.programs += 1
        return fn

    def _on_trace(self, op: str, nbits: int) -> None:
        """Python-side hook inside every jitted body: runs exactly on
        jit cache misses (fresh XLA traces).  After ``warm()`` has
        completed, any execution here breaks the zero-retrace contract
        -- tick the ``retraces_total`` metric and apply the configured
        ``on_retrace`` policy (repro/obs/retrace.py).  The one expected
        post-warm trace is the recompile a backend-tier degrade forces
        (``_expect_trace``); it is deliberate, not a contract break."""
        if self._warmed and not self._expect_trace:
            _retrace.alarm("serve", op=op, bits=nbits)

    def _execute(self, bkey: BucketKey,
                 reqs: List[BignumRequest]) -> np.ndarray:
        """Pad ``reqs`` to a full ``slots`` batch and run the bucket's
        compiled program; returns the (slots, limbs) result block."""
        op, nbits, ebits, _ = bkey
        slots = self.cfg.slots
        fn = self._fn(bkey, reqs[0])
        lw = nbits // 32 if op == "mod_exp" else -(-reqs[0].key.bits // 32)
        base = np.zeros((slots, lw), np.uint32)
        for i, r in enumerate(reqs):
            v = np.asarray(r.value, np.uint32).reshape(-1)
            base[i, : v.size] = v
        base[len(reqs):] = base[0]              # pad: repeat lane 0
        if op == "mod_exp":
            rows = [np.asarray(api.exp_bits_msb(r.exponent, ebits))
                    for r in reqs]
            rows += [rows[0]] * (slots - len(reqs))
            out = fn(base, np.stack(rows))
        else:
            out = fn(base)
        return np.asarray(jax.block_until_ready(out))

    # -- degradation ------------------------------------------------------

    def _tier_name(self, bkey: BucketKey) -> str:
        """Label of the backend tier this bucket currently runs at."""
        return self._degraded.get(bkey) or self.backend or "auto"

    def _next_tier(self, bkey: BucketKey) -> Optional[str]:
        """One step down the degradation ladder for this bucket, or
        None when the bucket already runs at the host-reference floor."""
        cur = self._degraded.get(bkey)
        if cur is None:
            return "reference" if self.backend == "jnp" else "jnp"
        if cur == "jnp":
            return "reference"
        return None

    def _degrade(self, bkey: BucketKey, exc: BaseException,
                 phase: str) -> bool:
        """Demote the bucket one tier after ``exc``; False when there is
        no tier left.  Drops the bucket's compiled program so the next
        run retraces at the demoted backend (an EXPECTED trace)."""
        nxt = self._next_tier(bkey)
        if nxt is None:
            return False
        _guard.tick(bkey[0], self._tier_name(bkey),
                    f"{phase}_{_guard.classify(exc)}")
        self.stats.degraded += 1
        self._degraded[bkey] = nxt
        self._fns.pop(bkey, None)
        return True

    def _execute_reference(self, bkey: BucketKey,
                           reqs: List[BignumRequest]) -> np.ndarray:
        """The host floor of the degradation ladder: python-int math per
        real lane, no jit, cannot fail on device state.  Same (slots,
        limbs) block contract as ``_execute`` (padded lanes zero)."""
        op, nbits, _, _ = bkey
        slots = self.cfg.slots
        lw = nbits // 32 if op == "mod_exp" else -(-reqs[0].key.bits // 32)
        out = np.zeros((slots, lw), np.uint32)
        for i, r in enumerate(reqs):
            v = api.from_limbs(np.asarray(r.value, np.uint32).reshape(-1))
            res = _selfcheck.repair_lane(
                op, v, modulus=r.modulus, exponent=r.exponent, key=r.key)
            out[i] = api.to_limbs(res, 32 * lw)
        return out

    def _run_batch(self, bkey: BucketKey,
                   reqs: List[BignumRequest]) -> np.ndarray:
        """Execute one batch with bounded retry, then degrade-and-rerun:
        transient failures get ``max_retries`` attempts (exponential
        backoff); a persistent failure demotes the bucket's backend tier
        and starts over.  Every request that enters here leaves with a
        result unless even the host-reference floor raises."""
        attempt = 0
        while True:
            try:
                _inject.fire(f"serve/flush/{bkey[0]}")
                if self._degraded.get(bkey) == "reference":
                    out = self._execute_reference(bkey, reqs)
                else:
                    out = self._execute(bkey, reqs)
                self._expect_trace = False
                return out
            except Exception as exc:                # noqa: BLE001
                if attempt < self.cfg.max_retries:
                    attempt += 1
                    self.stats.retries += 1
                    if self.cfg.retry_backoff_s:
                        time.sleep(
                            self.cfg.retry_backoff_s * 2 ** (attempt - 1))
                    continue
                if not self._degrade(bkey, exc, "flush"):
                    raise
                self._expect_trace = True
                attempt = 0

    # -- serving ----------------------------------------------------------

    def warm(self, op: str, *, modulus: Optional[int] = None,
             exponent: Optional[int] = None,
             key: Optional[api.RSAKey] = None) -> None:
        """Pre-compile the program for one (op, bucket, modulus) before
        traffic (for mod_exp, ``exponent`` is a representative value --
        only its quantized width matters).  Serving a warmed bucket
        never traces again: snapshot ``stats.traces`` after warming to
        assert the zero-retrace property (the runtime form of the same
        contract is the retrace alarm, armed once any warm() finishes
        -- see ``_on_trace``).

        Idempotent per bucket (re-warming a warmed key is a no-op, not a
        fresh trace) and degraded-not-fatal: a bucket whose warm-up
        raises is demoted a backend tier and re-warmed; warm only raises
        when even the host-reference floor fails."""
        if self._closed:
            raise RuntimeError(
                "BignumEngine is closed; warm() after close() is invalid "
                "-- create a new engine")
        sample = BignumRequest(rid=-1, op=op, value=np.zeros(1, np.uint32),
                               modulus=modulus, exponent=exponent, key=key)
        bkey = self.bucket_key(sample)
        if bkey in self._warmed_keys:
            return
        self._warmed = False            # warming traces are expected
        try:
            while True:
                try:
                    if self._degraded.get(bkey) == "reference":
                        self._execute_reference(bkey, [sample])
                    else:
                        self._execute(bkey, [sample])
                    break
                except Exception as exc:            # noqa: BLE001
                    if not self._degrade(bkey, exc, "warm"):
                        raise
            self._warmed_keys.add(bkey)
        finally:
            self._warmed = True

    def submit(self, req: BignumRequest, now: float = 0.0
               ) -> List[BignumRequest]:
        """Enqueue; flushes and returns the batch when it fills.

        Admission control runs first: when the engine is overloaded
        (queue depth >= ``max_queue``, or the oldest pending deadline
        has slipped more than ``max_wait_s`` past due) the request is
        SHED -- returned immediately with ``shed=True`` and no result,
        ticking ``shed_total{op}`` -- so overload degrades to bounded,
        observable rejections instead of unbounded queue growth."""
        if self._closed:
            raise RuntimeError(
                "BignumEngine is closed; submit() after close() is "
                "invalid -- create a new engine")
        bkey = self.bucket_key(req)
        req.arrival = now
        req.deadline = now + self.cfg.max_wait_s
        nd = self.next_deadline()
        if (self.pending() >= self.cfg.max_queue
                or (nd is not None and now - nd > self.cfg.max_wait_s)):
            req.shed = True
            self.stats.shed += 1
            _metrics.REGISTRY.counter(
                "shed_total", "requests rejected at admission").inc(
                op=req.op)
            return [req]
        q = self._queues.setdefault(bkey, [])
        q.append(req)
        if len(q) == 1:
            self._deadlines[bkey] = req.deadline
        if len(q) >= self.cfg.slots:
            return self._flush(bkey, "full", now)
        return []

    def close(self, drain: bool = True) -> List[BignumRequest]:
        """Graceful shutdown: drain every pending bucket (serving the
        queued requests), then mark the engine terminal.  With
        ``drain=False`` pending requests are returned UNSERVED (shed)
        instead of executed.  Idempotent; after close, submit()/warm()
        raise RuntimeError."""
        if self._closed:
            return []
        done: List[BignumRequest] = []
        if drain:
            while self.pending():
                done += self.drain_one()
        else:
            for q in self._queues.values():
                for r in q:
                    r.shed = True
                    self.stats.shed += 1
                done += q
            self._queues.clear()
            self._deadlines.clear()
        self._closed = True
        return done

    def next_deadline(self) -> Optional[float]:
        return min(self._deadlines.values(), default=None)

    def flush_next_due(self, now: float) -> List[BignumRequest]:
        """Serve the earliest bucket whose deadline has expired."""
        due = [(dl, k) for k, dl in self._deadlines.items() if dl <= now]
        if not due:
            return []
        _, bkey = min(due, key=lambda t: t[0])
        return self._flush(bkey, "deadline", now)

    def drain_one(self) -> List[BignumRequest]:
        """Force-flush one pending bucket (oldest deadline first)."""
        if not self._deadlines:
            return []
        bkey = min(self._deadlines, key=self._deadlines.get)
        return self._flush(bkey, "deadline", self._deadlines[bkey])

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _flush(self, bkey: BucketKey, reason: str,
               now: Optional[float] = None) -> List[BignumRequest]:
        reqs = self._queues.pop(bkey)
        deadline = self._deadlines.pop(bkey, None)
        traces0 = self.stats.traces
        t0 = time.perf_counter()
        try:
            out = self._run_batch(bkey, reqs)
        except Exception:
            # retries and degradation are exhausted: put the batch back
            # so close()/drain keep seeing it, then let the error surface
            self._queues[bkey] = reqs
            if deadline is not None:
                self._deadlines[bkey] = deadline
            raise
        dt = time.perf_counter() - t0
        op = bkey[0]
        # result-trimmed region: mod_exp pads to the bucket width but only
        # the natural modulus width is returned (all requests in a bucket
        # share bkey[3] = modulus / key.n), rsa_* returns full key width
        trim = (-(-bkey[3].bit_length() // 32) if op == "mod_exp"
                else out.shape[-1])
        view = out[:, :trim]
        sub = _inject.corrupt(f"serve/flush/{op}", view, len(reqs))
        if sub is not view:                      # fault injected: flipped
            out = np.array(out)                  # one bit of one real lane
            out[:, :trim] = sub
        if _selfcheck.enabled():
            out = self._selfcheck_batch(bkey, reqs, out, trim)
        for i, r in enumerate(reqs):
            r.result = out[i, :trim] if op == "mod_exp" else out[i]
        st = self.stats
        st.served += len(reqs)
        st.batches += 1
        st.padded_lanes += self.cfg.slots - len(reqs)
        if reason == "full":
            st.flush_full += 1
        else:
            st.flush_deadline += 1
        for r in reqs:
            if r.sla_s is None:
                continue
            wait = max(0.0, now - r.arrival) if now is not None else 0.0
            if wait + dt > r.sla_s:
                st.deadline_misses += 1
                _metrics.REGISTRY.counter(
                    "deadline_miss_total",
                    "served requests whose latency exceeded sla_s").inc(
                    op=op, bits=bkey[1])
        if obs.enabled():
            self._observe_flush(bkey, reqs, reason, now, t0, dt,
                                traced=self.stats.traces > traces0)
        return list(reqs)

    def _selfcheck_batch(self, bkey: BucketKey, reqs: List[BignumRequest],
                         out: np.ndarray, trim: int) -> np.ndarray:
        """Residue/witness-verify every REAL lane of a flushed batch and
        repair mismatches from the host-int reference before results are
        handed out.  Each bad lane ticks ``selfcheck_failures_total``
        and ``fallback_total{reason="selfcheck"}``; the configured
        policy (warn/raise) fires AFTER repair, so even "raise" callers
        can recover served-but-flagged results from the request
        objects."""
        op, nbits, _, _ = bkey
        bad = 0
        for i, r in enumerate(reqs):
            v = api.from_limbs(np.asarray(r.value, np.uint32).reshape(-1))
            res = api.from_limbs(out[i, :trim])
            if _selfcheck.verify_lane(op, v, res, modulus=r.modulus,
                                      exponent=r.exponent, key=r.key):
                continue
            if bad == 0:
                out = np.array(out)
            bad += 1
            good = _selfcheck.repair_lane(op, v, modulus=r.modulus,
                                          exponent=r.exponent, key=r.key)
            out[i, :trim] = api.to_limbs(good, 32 * trim)
        if bad:
            self.stats.selfcheck_failures += bad
            _guard.tick(op, self._tier_name(bkey), "selfcheck", amount=bad)
            _selfcheck.report(op, bad, "serve flush lane verification",
                              bits=nbits)
        return out

    def _observe_flush(self, bkey: BucketKey, reqs: List[BignumRequest],
                       reason: str, now: Optional[float], t0: float,
                       dt: float, traced: bool) -> None:
        """Mirror one flush into the metrics registry + span buffer
        (only called with observability on).

        Request latency = virtual queue wait (``now`` - arrival, on the
        caller's clock) + the REAL measured service time of this flush
        -- the same accounting replay_trace uses, so the histogram
        p50/p95/p99 agree with ReplayResult on a replayed trace.  The
        span category is "trace" iff this flush compiled (the jitted
        body ran), which is exactly the seconds-vs-milliseconds split
        the engine exists to manage."""
        op, nbits, _, _ = bkey
        r = obs.REGISTRY
        labels = {"op": op, "bits": nbits}
        obs.spans.record(f"serve/{op}/{nbits}", "trace" if traced
                         else "execute", t0, dt,
                         batch=len(reqs), reason=reason)
        r.counter("serve_requests_total",
                  "requests served by the batching engine").inc(
            len(reqs), **labels)
        r.counter("serve_batches_total",
                  "engine flushes by trigger").inc(reason=reason, **labels)
        r.counter("serve_padded_lanes_total",
                  "slots padded by repeating lane 0").inc(
            self.cfg.slots - len(reqs), **labels)
        hist = r.histogram("serve_request_latency_seconds",
                           "queue wait + measured service time")
        for q in reqs:
            wait = max(0.0, now - q.arrival) if now is not None else 0.0
            hist.observe(wait + dt, **labels)
        r.gauge("serve_queue_depth",
                "requests enqueued across buckets").set(self.pending())


# ---------------------------------------------------------------------------
# one-at-a-time baseline
# ---------------------------------------------------------------------------

class NaiveServer:
    """One-request-at-a-time baseline: every call runs at batch 1 and
    its NATURAL width, jit-cached per (op, modulus, exponent width).  A
    shape-following server like this retraces whenever a new natural
    width or modulus shows up in traffic; ``warm()`` grants it the same
    finite-key head start the engine gets, which isolates the batching
    win from the retrace win in the benchmarks."""

    def __init__(self, *, backend: Optional[str] = None):
        self.backend = backend
        self.stats = EngineStats()
        self._fns: Dict[tuple, Callable] = {}

    def _fn(self, req: BignumRequest) -> Callable:
        if req.op not in OPS:
            raise ValueError(
                f"unknown serve op {req.op!r}; choose from {OPS}")
        if req.op == "mod_exp":
            key = (req.op, req.modulus, max(1, req.exponent.bit_length()))
        else:
            key = (req.op, req.key.n)
        if key in self._fns:
            return self._fns[key]
        stats = self.stats
        backend = self.backend
        if req.op == "mod_exp":
            ctx = api.mod_setup(req.modulus)

            def body(base, exp_bits, _ctx=ctx):
                stats.traces += 1
                return api.mod_exp(base, exp_bits, _ctx, backend=backend)
        elif req.op == "rsa_decrypt":
            k, crt = req.key, req.key.p != 0

            def body(base, _key=k, _crt=crt):
                stats.traces += 1
                return api.rsa_decrypt(base, _key, backend=backend,
                                       crt=_crt)
        else:
            f = api.rsa_sign if req.op == "rsa_sign" else api.rsa_verify
            k = req.key

            def body(base, _f=f, _key=k):
                stats.traces += 1
                return _f(base, _key, backend=backend)
        fn = jax.jit(body)
        self._fns[key] = fn
        stats.programs += 1
        return fn

    def serve(self, req: BignumRequest) -> np.ndarray:
        fn = self._fn(req)
        if req.op == "mod_exp":
            lw = -(-req.modulus.bit_length() // 32)
        else:
            lw = -(-req.key.bits // 32)
        base = np.zeros((1, lw), np.uint32)
        v = np.asarray(req.value, np.uint32).reshape(-1)
        base[0, : v.size] = v
        if req.op == "mod_exp":
            eb = np.asarray(api.exp_bits_msb(req.exponent))[None]
            out = fn(base, eb)
        else:
            out = fn(base)
        out = np.asarray(jax.block_until_ready(out))
        req.result = out[0, :lw]
        self.stats.served += 1
        self.stats.batches += 1
        return req.result

    def warm(self, op: str, *, modulus: Optional[int] = None,
             exponent: Optional[int] = None,
             key: Optional[api.RSAKey] = None) -> None:
        self.serve(BignumRequest(rid=-1, op=op,
                                 value=np.zeros(1, np.uint32),
                                 modulus=modulus, exponent=exponent,
                                 key=key))
        self.stats.served -= 1          # warm-ups don't count as traffic
        self.stats.batches -= 1


# ---------------------------------------------------------------------------
# trace replay (virtual arrival clock, real measured service times)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplayResult:
    n: int
    makespan_s: float
    ops_per_s: float
    p50_ms: float
    p99_ms: float
    mean_ms: float


def _summarize(reqs: List[BignumRequest]) -> ReplayResult:
    lats = np.array([r.latency for r in reqs]) * 1e3
    t0 = min(r.arrival for r in reqs)
    t1 = max(r.completion for r in reqs)
    makespan = max(t1 - t0, 1e-12)
    return ReplayResult(len(reqs), makespan, len(reqs) / makespan,
                        float(np.percentile(lats, 50)),
                        float(np.percentile(lats, 99)),
                        float(lats.mean()))


def replay_trace(engine: BignumEngine,
                 trace: List[BignumRequest]) -> ReplayResult:
    """Event-driven replay: arrivals advance a virtual clock; each
    engine call that completes requests is timed for real (the engine
    blocks on device results) and that wall time becomes the service
    time on the virtual clock.  The single device is a serial server:
    work triggered at virtual time t starts at max(t, server-free)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    free = 0.0
    done: List[BignumRequest] = []
    i = 0
    while i < len(trace) or engine.pending():
        nxt = trace[i].arrival if i < len(trace) else float("inf")
        dl = engine.next_deadline()
        if dl is not None and dl <= nxt:
            start = max(dl, free)
            t0 = time.perf_counter()
            reqs = engine.flush_next_due(dl)
            dt = time.perf_counter() - t0
        else:
            r = trace[i]
            i += 1
            start = max(r.arrival, free)
            t0 = time.perf_counter()
            reqs = engine.submit(r, r.arrival)
            dt = time.perf_counter() - t0
        if reqs:
            free = start + dt
            for q in reqs:
                q.completion = free
            done += reqs
    return _summarize(done)


def replay_naive(server: NaiveServer,
                 trace: List[BignumRequest]) -> ReplayResult:
    """Same replay model for the one-at-a-time baseline: each request
    is served alone the moment the server frees up after its arrival
    (compile time, if the shape/modulus is new, lands in its service
    time -- that's the cost a shape-following server actually pays)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    free = 0.0
    for r in trace:
        start = max(r.arrival, free)
        t0 = time.perf_counter()
        server.serve(r)
        dt = time.perf_counter() - t0
        r.completion = start + dt
        free = r.completion
    return _summarize(trace)


def poisson_trace(ops: List[dict], n: int, rate_per_s: float,
                  seed: int = 0) -> List[BignumRequest]:
    """n requests with exponential interarrivals at ``rate_per_s``,
    cycling through ``ops`` (dicts of BignumRequest kwargs minus
    rid/arrival) in round-robin so every replay sees the same op mix
    regardless of rate."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    t = 0.0
    out = []
    for i in range(n):
        t += float(gaps[i])
        out.append(BignumRequest(rid=i, arrival=t, **ops[i % len(ops)]))
    return out
