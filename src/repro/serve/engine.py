"""Batched serving engine: slot-based continuous batching over the
model's prefill/decode_step functions.

Requests are packed into fixed `slots` (padded batch); each decode step
advances every active slot by one token; finished slots (EOS or
max_new_tokens) are refilled from the queue without disturbing the
others (their cache rows are overwritten by the next prefill-into-slot).
This is the vLLM-style serving loop reduced to its JAX essentials: all
steps are fixed-shape, so one compiled prefill + one compiled decode
serve every request mix.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                # concurrent sequences (compiled batch)
    max_seq: int = 256            # cache allocation
    eos_id: int = -1              # -1: never stop early
    greedy: bool = True


class ServeEngine:
    """Single-host engine; the launch/serve.py driver adds mesh sharding."""

    def __init__(self, model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.vocab = model.cfg.vocab_size

        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _zero_cache(self):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.model.cache_specs(self.cfg.slots, self.cfg.max_seq))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion; returns {rid: generated tokens}.

        Simplification vs production: requests are served in waves of
        `slots` with a shared position clock (prompts padded left to the
        wave's max prompt length); a per-slot clock needs per-slot cache
        indices, noted in DESIGN.md as the continuous-batching extension.
        """
        cfg = self.cfg
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[: cfg.slots]
            queue = queue[cfg.slots:]
            n = len(wave)
            plen = max(len(r.prompt) for r in wave)
            toks = np.zeros((cfg.slots, plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            cache = self._zero_cache()
            batch = {"tokens": jnp.asarray(toks)}
            logits, cache = self._prefill(self.params, batch, cache)
            max_new = max(r.max_new_tokens for r in wave)
            outs = [[] for _ in range(n)]
            done = [False] * n
            cur = jnp.argmax(
                logits[:, : self.vocab], axis=-1).astype(jnp.int32)
            for step in range(max_new):
                for i in range(n):
                    if not done[i] and len(outs[i]) < wave[i].max_new_tokens:
                        t = int(cur[i])
                        outs[i].append(t)
                        if t == cfg.eos_id:
                            done[i] = True
                    else:
                        done[i] = True
                if all(done):
                    break
                logits, cache = self._decode(
                    self.params, cache, cur[:, None],
                    jnp.int32(plen + step))
                cur = jnp.argmax(
                    logits[:, : self.vocab], axis=-1).astype(jnp.int32)
            for r, o in zip(wave, outs):
                results[r.rid] = o
        return results
