"""Training step construction: microbatch accumulation, exact deferred-
carry gradient reduction (the paper's technique as a training feature),
and optional int8 error-feedback gradient compression.

Gradient-reduction modes:
  "mean"  : plain f32 accumulation (baseline; order-DEPENDENT bits).
  "exact" : every microbatch gradient is quantized to DoT digit planes and
            accumulated with carry-free integer adds (core/exact_accum);
            one carry resolve + decode at the end.  Bitwise invariant to
            microbatch order AND count for a fixed global batch -- with
            the integer psum in distributed/collectives.py this extends to
            replica count, the property that makes elastic re-scaling
            bit-reproducible.
  "int8_ef": int8-quantized gradients with error feedback (bandwidth
            optimization for the collective-bound regime; see
            distributed/collectives.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import exact_accum as EA
from repro.train import optimizer as OPT

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    opt: OPT.OptConfig = OPT.OptConfig()
    microbatches: int = 1
    grad_reduce: str = "mean"           # mean | exact | int8_ef
    accum: EA.ExactAccumConfig = EA.ExactAccumConfig()


def _split_microbatches(batch, k: int):
    def sp(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model, tcfg: TrainerConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    k = tcfg.microbatches

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, mb)
        return loss, metrics, grads

    def accumulate_grads(params, batch):
        if k == 1:
            return grads_of(params, batch)
        mbs = _split_microbatches(batch, k)

        if tcfg.grad_reduce == "exact":
            # deferred-carry integer accumulation (order-invariant);
            # grads mirror the param tree, so params are the shape template
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape + (tcfg.accum.num_limbs,),
                                    jnp.uint32), params)

            def body(carry, mb):
                acc, loss_sum = carry
                loss, _, g = grads_of(params, mb)
                enc = jax.tree.map(lambda x: EA.encode(x, tcfg.accum), g)
                acc = jax.tree.map(EA.accumulate, acc, enc)
                return (acc, loss_sum + loss), None

            (acc, loss_sum), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), F32)), mbs)
            grads = jax.tree.map(
                lambda d: EA.decode(EA.normalize(d, tcfg.accum), tcfg.accum)
                / k, acc)
            return loss_sum / k, {}, grads

        def body(carry, mb):
            loss_sum, g_acc = carry
            loss, _, g = grads_of(params, mb)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(F32), g_acc, g)
            return (loss_sum + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        (loss_sum, g_acc), _ = jax.lax.scan(
            body, (jnp.zeros((), F32), g0), mbs)
        grads = jax.tree.map(lambda g: g / k, g_acc)
        return loss_sum / k, {}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = accumulate_grads(params, batch)
        params, opt_state, om = OPT.update(tcfg.opt, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def train_loop(model, tcfg: TrainerConfig, data, steps: int,
               params=None, opt_state=None, callbacks=(),
               key=None):
    """Single-host training driver (examples + tests; launch/train.py is
    the production entry with mesh/sharding/checkpointing)."""
    key = key if key is not None else jax.random.key(0)
    params = params if params is not None else model.init(key)
    opt_state = opt_state if opt_state is not None else OPT.init(params)
    step_fn = jax.jit(make_train_step(model, tcfg))
    history = []
    for step in range(steps):
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        history.append({k: float(v) for k, v in metrics.items()})
        for cb in callbacks:
            cb(step, params, opt_state, history[-1])
    return params, opt_state, history
