"""AdamW with warmup-cosine schedule (self-contained; no optax).

The optimizer state mirrors the param tree, so the param sharding rules
apply verbatim to m/v (distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(F32)
    c2 = 1 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"],
                        is_leaf=lambda x: False)
    # unzip the (p, m, v) tuples
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_opt = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_opt, {"lr": lr, "grad_norm": gnorm}
