"""Fault tolerance: restart management, straggler detection, elastic plans.

Designed for the 1000+-node regime:
  * RestartManager -- resume from the newest VALID checkpoint, walking
    backwards past corrupted ones (integrity = CRC + RSA signature from
    train/checkpoint.py); a crash between save and prune is safe because
    saves are atomic.
  * StragglerMonitor -- per-step wall-time EWMA + median window; flags
    outliers (slow host / failing HBM / thermal throttle) and recommends
    an action.  On a real pod the action hooks into the job controller
    (hot-spare swap / checkpoint-and-restart without the straggler).
  * ElasticPlan -- given a new chip count, produce the new mesh shape and
    resharding plan; checkpoints are layout-free so restore-on-new-mesh
    is just device_put with new shardings (tested in
    tests/test_distributed.py with subprocess device counts).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Optional


from repro.train import checkpoint as CKPT


class RestartManager:
    def __init__(self, ckpt_dir):
        self.ckpt_dir = ckpt_dir

    def latest_valid_step(self) -> Optional[int]:
        for step in reversed(CKPT.list_steps(self.ckpt_dir)):
            path = f"{self.ckpt_dir}/step_{step:09d}"
            try:
                CKPT.validate(path)
                return step
            except CKPT.CheckpointError:
                continue
        return None

    def resume(self, state_template, shardings=None):
        """Returns (step, state) from the newest valid checkpoint, or
        (None, None) for a cold start."""
        step = self.latest_valid_step()
        if step is None:
            return None, None
        state, _ = CKPT.restore(
            f"{self.ckpt_dir}/step_{step:09d}", state_template,
            shardings=shardings)
        return step, state


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float
    ratio: float
    action: str


class StragglerMonitor:
    """Flags steps slower than `threshold` x rolling median."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 trip_count: int = 3):
        self.window = window
        self.threshold = threshold
        self.trip_count = trip_count
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []
        self._consecutive = 0
        self._last = None

    def start(self):
        self._last = time.monotonic()

    def stop(self, step: int) -> Optional[StragglerEvent]:
        assert self._last is not None
        dt = time.monotonic() - self._last
        return self.record(step, dt)

    def record(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        self.times.append(step_time)
        hist = self.times[-self.window:]
        if len(hist) < 5:
            return None
        med = statistics.median(hist[:-1])
        ratio = step_time / max(med, 1e-9)
        if ratio >= self.threshold:
            self._consecutive += 1
            action = ("checkpoint_and_replace_host"
                      if self._consecutive >= self.trip_count
                      else "observe")
            ev = StragglerEvent(step, step_time, med, ratio, action)
            self.events.append(ev)
            return ev
        self._consecutive = 0
        return None


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_chips: int
    new_chips: int
    new_mesh_shape: tuple
    new_axes: tuple
    notes: str


def plan_elastic(new_chips: int, model_parallel: int = 16,
                 pod_size: int = 256) -> ElasticPlan:
    """Pick a mesh for an arbitrary surviving-chip count.

    Policy: keep TP fixed (model quality/latency invariant), scale DP;
    round DOWN to a multiple of model_parallel; multi-pod when the count
    exceeds one pod.  Because gradient reduction uses exact integer
    limbs (core/exact_accum), changing the DP extent preserves bitwise
    training reproducibility for a fixed global batch.
    """
    usable = (new_chips // model_parallel) * model_parallel
    if usable == 0:
        raise ValueError(f"need at least {model_parallel} chips")
    data = usable // model_parallel
    if usable > pod_size:
        pods = usable // pod_size
        data = pod_size // model_parallel
        return ElasticPlan(0, usable, (pods, data, model_parallel),
                           ("pod", "data", "model"),
                           f"dropped {new_chips - pods * pod_size} chips")
    return ElasticPlan(0, usable, (data, model_parallel), ("data", "model"),
                       f"dropped {new_chips - usable} chips")
