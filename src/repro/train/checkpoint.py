"""Checkpointing with integrity verification and elastic restore.

Layout (one directory per step, atomically renamed into place):
  ckpt_dir/step_000123/
    manifest.json   -- tree structure, shapes, dtypes, per-leaf CRC32,
                       RSA signature of the manifest digest (signed with
                       the framework's OWN bignum stack: core/rsa.py)
    arr_00000.npy ... one file per leaf

Fault-tolerance contract:
  * save is atomic (tmp dir + rename): a crash mid-save never corrupts
    the latest checkpoint;
  * restore validates every CRC and the manifest signature, and the
    RestartManager (fault_tolerance.py) falls back to the previous step
    on corruption;
  * arrays are stored UNSHARDED with their PartitionSpec recorded, so a
    restore may target ANY mesh shape (elastic re-scaling): pass new
    shardings and the loader device_puts accordingly.  (On a real
    multi-host pod each host writes its local shards; the manifest
    format already records specs per leaf -- see DESIGN.md "multi-host
    checkpointing".)
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Optional

import jax
import numpy as np

from repro.core import limbs as L
from repro.core import rsa as RSA

_SIGN_KEY_SEED = 1337
_sign_key_cache: dict = {}


def _sign_key() -> RSA.RSAKey:
    if "k" not in _sign_key_cache:
        _sign_key_cache["k"] = RSA.generate_key(bits=256, seed=_SIGN_KEY_SEED)
    return _sign_key_cache["k"]


def _tree_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, state, *, keep_last: int = 3,
         extra_meta: Optional[dict] = None, sign: bool = True) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _tree_paths(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [],
        "extra": extra_meta or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    digest_src = json.dumps(manifest, sort_keys=True).encode()
    if sign:
        key = _sign_key()
        msg = RSA.digest_int(digest_src, key.bits)
        sig = RSA.sign(RSA.messages_to_digits([msg], key), key)
        manifest["signature"] = {
            "msg": msg,
            "sig": L.limbs_to_int(np.asarray(sig)[0], 16),
            "n": key.n, "e": key.e,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest))

    final = ckpt_dir / f"step_{step:09d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    # prune old checkpoints
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)
    return final


class CheckpointError(RuntimeError):
    pass


def validate(path) -> dict:
    """Raises CheckpointError on any integrity violation; returns manifest."""
    path = pathlib.Path(path)
    mf_path = path / "manifest.json"
    if not mf_path.exists():
        raise CheckpointError(f"{path}: no manifest")
    manifest = json.loads(mf_path.read_text())
    sig = manifest.pop("signature", None)
    digest_src = json.dumps(manifest, sort_keys=True).encode()
    if sig is not None:
        key = _sign_key()
        if sig["n"] != key.n:
            raise CheckpointError(f"{path}: unknown signing key")
        want = RSA.digest_int(digest_src, key.bits)
        if want != sig["msg"]:
            raise CheckpointError(f"{path}: manifest digest mismatch")
        back = RSA.verify(RSA.messages_to_digits([sig["sig"]], key), key)
        if L.limbs_to_int(np.asarray(back)[0], 16) != sig["msg"]:
            raise CheckpointError(f"{path}: RSA signature invalid")
    for leaf in manifest["leaves"]:
        f = path / leaf["file"]
        if not f.exists():
            raise CheckpointError(f"{path}: missing {leaf['file']}")
        arr = np.load(f)
        if zlib.crc32(arr.tobytes()) != leaf["crc32"]:
            raise CheckpointError(f"{path}: CRC mismatch in {leaf['file']}")
    manifest["signature"] = sig
    return manifest


def restore(path, state_template, *, shardings=None):
    """Load a validated checkpoint into the template's tree structure.

    shardings: optional tree (matching template) of NamedSharding for
    elastic restore onto any mesh.
    """
    path = pathlib.Path(path)
    validate(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _tree_paths(state_template)
    if len(leaves) != len(manifest["leaves"]):
        raise CheckpointError(
            f"leaf count mismatch: {len(leaves)} vs {len(manifest['leaves'])}")
    arrs = [np.load(path / l["file"]) for l in manifest["leaves"]]
    out = jax.tree_util.tree_unflatten(treedef, arrs)
    if shardings is not None:
        out = jax.tree.map(jax.device_put, out, shardings)
    else:
        out = jax.tree.map(jax.numpy.asarray, out)
    return out, manifest


def list_steps(ckpt_dir):
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
                  if p.name.startswith("step_"))


class AsyncSaver:
    """Overlap checkpoint writes with training (one in flight)."""

    def __init__(self, ckpt_dir, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state, **kw):
        self.wait()
        # materialize on host BEFORE returning control (donation safety)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_state),
            kwargs={"keep_last": self.keep_last, **kw}, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
