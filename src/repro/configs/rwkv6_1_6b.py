"""rwkv6-1.6b "Finch" [ssm]: 24L d2048 (attention-free) d_ff=7168
vocab=65536, data-dependent per-channel decay.

[arXiv:2404.05892; unverified]  Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, vocab_size=65536, d_ff=7168,
    rwkv_head_dim=64, rwkv_chunk=32, sub_quadratic=True,
    tie_embeddings=False,
    remat="dots",   # small model: saving matmul outputs avoids
    # re-running forward collectives during backward (SSPerf cell 2, iter 1)
)

REDUCED = CONFIG.replace(
    name="rwkv6-1.6b-reduced", num_layers=2, d_model=128, d_ff=256,
    vocab_size=256, rwkv_head_dim=32, rwkv_chunk=8, q_chunk=64)
