"""seamless-m4t-large-v2 [audio]: enc-dec 24L+24L d1024 16H (kv=16)
d_ff=8192 vocab=256206.  The speech frontend is a STUB: input_specs
provides precomputed frame embeddings (seq_len // 4 frames at d_model).

[arXiv:2308.11596; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=48, enc_layers=24, dec_layers=24,
    d_model=1024, vocab_size=256206, d_ff=8192,
    num_heads=16, num_kv_heads=16, head_dim=64,
    enc_frames_ratio=4, tie_embeddings=False,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="seamless-reduced", num_layers=4, enc_layers=2, dec_layers=2,
    d_model=128, d_ff=256, num_heads=4, num_kv_heads=4, head_dim=32,
    vocab_size=256, q_chunk=64)
