"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
anyres tiling -> 2880 image tokens (frontend STUB: input_specs provides
precomputed patch embeddings at d_model).

[hf:llava-hf/llava-v1.6-34b-hf; unverified]
TP note: 56 q-heads are not divisible by the 16-way model axis; the
dry-run config pads q-heads to 64 (kv stays 8; group=8).  Recorded in
DESIGN.md SSArch-applicability.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, vocab_size=64000, d_ff=20480,
    num_heads=56, num_kv_heads=8, head_dim=128,
    num_image_tokens=2880, pad_heads_to=64, rope_theta=5_000_000.0,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="llava-next-34b-reduced", num_layers=2, d_model=128, d_ff=256,
    num_heads=4, num_kv_heads=2, head_dim=32, vocab_size=256,
    num_image_tokens=8, pad_heads_to=0, q_chunk=64)
