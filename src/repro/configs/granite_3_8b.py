"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) d_ff=12800 vocab=49155.

[hf:ibm-granite/granite-3.0-8b-base; hf]  Llama-style GQA dense decoder.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    num_layers=40, d_model=4096, vocab_size=49155, d_ff=12800,
    num_heads=32, num_kv_heads=8, head_dim=128,
    rope_theta=10_000_000.0,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="granite-3-8b-reduced", num_layers=2, d_model=128, d_ff=256,
    num_heads=4, num_kv_heads=2, head_dim=32, vocab_size=256, q_chunk=64)
