"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d2048, ssm_state=64, plus ONE
weight-shared attention block (32H kv=32, d_ff 8192) applied every 6
mamba layers on concat(hidden, embeddings).

[arXiv:2411.15242; hf]  Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, vocab_size=32000, d_ff=8192,
    num_heads=32, num_kv_heads=32, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=128,
    shared_attn_every=6, sub_quadratic=True,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="zamba2-1.2b-reduced", num_layers=5, d_model=128, d_ff=256,
    num_heads=4, num_kv_heads=4, head_dim=32,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=16,
    shared_attn_every=2, vocab_size=256, q_chunk=64)
