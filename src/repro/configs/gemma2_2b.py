"""gemma2-2b [dense]: 26L d2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

[arXiv:2408.00118; hf]  Alternating local(4096)/global attention,
attn logit softcap 50, final softcap 30, GeGLU, post-block norms,
sqrt(d) embedding scaling.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, vocab_size=256000, d_ff=9216,
    num_heads=8, num_kv_heads=4, head_dim=256,
    attn_pattern="local_global", local_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    mlp_act="gelu", embed_scale=True,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="gemma2-2b-reduced", num_layers=4, d_model=128, d_ff=256,
    num_heads=4, num_kv_heads=2, head_dim=32, vocab_size=256,
    local_window=16, q_chunk=64)
