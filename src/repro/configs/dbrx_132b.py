"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) d_ff=10752/expert
vocab=100352, 16 experts top-4 (fine-grained).

[hf:databricks/dbrx-base; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, vocab_size=100352, d_ff=10752,
    num_heads=48, num_kv_heads=8, head_dim=128,
    num_experts=16, top_k=4, rope_theta=500_000.0,
    capacity_factor=1.0,   # SSPerf cell 1 iter 5: buffers scale with cf

    remat="full",
)

REDUCED = CONFIG.replace(
    name="dbrx-132b-reduced", num_layers=2, d_model=128, d_ff=128,
    num_heads=4, num_kv_heads=2, head_dim=32, vocab_size=256,
    num_experts=4, top_k=2, q_chunk=64)
