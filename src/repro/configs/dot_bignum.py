"""The paper's own workload config: DoT large-number arithmetic.

Operand sizes follow the paper's evaluation grid (sec 4): twelve sizes
from 512 to 32768 bits, batched to fill TPU lanes; 256-bit base-case
multiplication (Table 4); GMPbench-style end-to-end apps (pi, modexp).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class DoTBenchConfig:
    operand_bits: Tuple[int, ...] = (
        512, 1024, 2048, 3072, 4096, 6144, 8192, 12288,
        16384, 20480, 24576, 32768)
    batch: int = 4096                 # independent operations per call
    mul_base_bits: int = 256          # base-case multiply (Table 4)
    karatsuba_threshold_digits: int = 16
    pathological_batch: int = 64
    rsa_bits: Tuple[int, ...] = (512, 1024, 2048)
    pi_digits: int = 1000


CONFIG = DoTBenchConfig()
REDUCED = DoTBenchConfig(
    operand_bits=(512, 1024), batch=64, pathological_batch=8,
    rsa_bits=(512,), pi_digits=100)
