"""The paper's own workload config: DoT large-number arithmetic.

Operand sizes follow the paper's evaluation grid (sec 4): twelve sizes
from 512 to 32768 bits, batched to fill TPU lanes; 256-bit base-case
multiplication (Table 4); GMPbench-style end-to-end apps (pi, modexp).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class MulDispatchConfig:
    """Size thresholds for core/mul.select_method (the unified multiply
    pipeline front door).  Bits are operand widths; boundaries follow the
    kernel ranges: the fused Karatsuba kernel's overflow analysis covers
    512..4096 bits, below that a single VnC base-case launch wins, and at
    tiny widths kernel-launch overhead dominates so the jnp composition
    is used directly."""

    jnp_max_bits: int = 256           # <= : jnp VnC ("dot")
    vnc_max_bits: int = 512           # <= : Pallas VnC kernel ("pallas")
    fused_kara_max_bits: int = 4096   # <= : fused Karatsuba ("pallas_kara")
    mxu_max_bits: int = 4096          # <= : int8 Toeplitz ("pallas_mxu")
    kara_threshold_digits: int = 32   # leaf width inside the fused kernel
    # Below this many independent operations a kernel launch cannot
    # amortize (the kernels tile the BATCH axis); small batches take the
    # jnp compositions instead: the quadratic VnC outer product while its
    # working set stays small, jnp Karatsuba beyond.
    kernel_min_batch: int = 8
    small_batch_dot_max_bits: int = 4096


MUL_DISPATCH = MulDispatchConfig()


@dataclasses.dataclass(frozen=True)
class DivDispatchConfig:
    """Size thresholds for core/div.select_div_method (division front
    door).  Up to ``schoolbook_max_bits`` the fused Knuth-D Pallas
    kernel wins (O(na*nb) VMEM-resident digit steps, one launch); above
    it the Newton reciprocal-divide path wins because its multiplies
    ride the autotuned pipeline's subquadratic backends."""

    schoolbook_max_bits: int = 512    # <= : Pallas Knuth-D ("schoolbook")
    #  > : Newton reciprocal + pipeline multiplies ("recip").  The
    # boundary matches MUL_DISPATCH.vnc_max_bits: the same regime where
    # a single fused launch beats composition (and where the kernel's
    # O(na*nb) unrolled step count stays cheap to compile).


DIV_DISPATCH = DivDispatchConfig()


@dataclasses.dataclass(frozen=True)
class DoTBenchConfig:
    operand_bits: Tuple[int, ...] = (
        512, 1024, 2048, 3072, 4096, 6144, 8192, 12288,
        16384, 20480, 24576, 32768)
    batch: int = 4096                 # independent operations per call
    mul_base_bits: int = 256          # base-case multiply (Table 4)
    karatsuba_threshold_digits: int = 16
    pathological_batch: int = 64
    rsa_bits: Tuple[int, ...] = (512, 1024, 2048)
    pi_digits: int = 1000


CONFIG = DoTBenchConfig()
REDUCED = DoTBenchConfig(
    operand_bits=(512, 1024), batch=64, pathological_batch=8,
    rsa_bits=(512,), pi_digits=100)
