"""The paper's own workload config: DoT large-number arithmetic.

Operand sizes follow the paper's evaluation grid (sec 4): twelve sizes
from 512 to 32768 bits, batched to fill TPU lanes; 256-bit base-case
multiplication (Table 4); GMPbench-style end-to-end apps (pi, modexp).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class MulDispatchConfig:
    """Size thresholds for core/mul.select_method (the unified multiply
    pipeline front door).  Bits are operand widths; boundaries follow the
    kernel ranges: the fused Karatsuba kernel's overflow analysis covers
    512..4096 bits, below that a single VnC base-case launch wins, and at
    tiny widths kernel-launch overhead dominates so the jnp composition
    is used directly."""

    jnp_max_bits: int = 256           # <= : jnp VnC ("dot")
    vnc_max_bits: int = 512           # <= : Pallas VnC kernel ("pallas")
    fused_kara_max_bits: int = 4096   # <= : fused Karatsuba ("pallas_kara")
    mxu_max_bits: int = 4096          # <= : int8 Toeplitz ("pallas_mxu")
    kara_threshold_digits: int = 32   # leaf width inside the fused kernel
    # >= : fused NTT/CRT kernels ("ntt") -- the huge-operand tier.  Between
    # fused_kara_max_bits and here the jnp Karatsuba composition still wins
    # (the NTT's fixed per-launch transform work isn't yet amortized);
    # from 8192 bits up the O(n log n) butterflies beat the composition
    # AND its trace/compile cost, which grows with the recursion tree.
    ntt_min_bits: int = 8192
    # CRT prime-set size for the NTT tier.  2 primes (~2**56 modulus) are
    # exact to ~2**24 digits -- far past the 64K-bit design point; 3
    # (~2**86) stay selectable for validation and wider future radices.
    ntt_primes: int = 2
    # Below this many independent operations a kernel launch cannot
    # amortize (the kernels tile the BATCH axis); small batches take the
    # jnp compositions instead: the quadratic VnC outer product while its
    # working set stays small.  Above the dot range the NTT kernel runs
    # even at batch 1: unlike the quadratic-unroll kernels (and the jnp
    # Karatsuba composition, whose XLA compile takes minutes past 4096
    # bits), its trace is O(log n) stages, so a batch-1 launch still
    # compiles in seconds and the O(n log n) work wins outright.
    kernel_min_batch: int = 8
    small_batch_dot_max_bits: int = 4096


MUL_DISPATCH = MulDispatchConfig()


@dataclasses.dataclass(frozen=True)
class DivDispatchConfig:
    """Size thresholds for core/div.select_div_method (division front
    door).  Up to ``schoolbook_max_bits`` the fused Knuth-D Pallas
    kernel wins (O(na*nb) VMEM-resident digit steps, one launch); above
    it the Newton reciprocal-divide path wins because its multiplies
    ride the autotuned pipeline's subquadratic backends."""

    schoolbook_max_bits: int = 512    # <= : Pallas Knuth-D ("schoolbook")
    #  > : Newton reciprocal + pipeline multiplies ("recip").  The
    # boundary matches MUL_DISPATCH.vnc_max_bits: the same regime where
    # a single fused launch beats composition (and where the kernel's
    # O(na*nb) unrolled step count stays cheap to compile).


DIV_DISPATCH = DivDispatchConfig()


@dataclasses.dataclass(frozen=True)
class ModExpDispatchConfig:
    """Dispatch knobs for core/modular.mod_exp (the modexp front door).

    Every backend runs the SAME fixed-window (k-ary) constant-time
    ladder schedule; these knobs pick the window size and which backend
    executes it.  ``window_bits`` caps the window chosen by
    ``pick_modexp_window`` (w=4 is the paper-standard sweet spot: the
    2**w-entry table stays tiny while the per-bit multiply count drops
    from 2 to 1 + 1/w).  The fused full-ladder Pallas kernel
    (kernels/dot_modmul) only amortizes over the batch axis, so below
    ``fused_min_batch`` independent exponentiations the jnp windowed
    composition is used instead (same regime as MUL_DISPATCH.
    kernel_min_batch); ``fused_max_bits`` bounds the kernel's VMEM
    working set (the 2**w-row power table is the dominant term, see
    kernels/README.md)."""

    window_bits: int = 4              # max window size w (table = 2**w rows)
    fused_min_batch: int = 8          # batch that fills a tile outright
    fused_max_bits: int = 8192        # above: jnp windowed ladder
    # Exponents shorter than this skip the fused kernel: at a handful of
    # windows the table build dominates and a kernel launch cannot pay
    # for itself (e.g. RSA verify with e = 65537).
    fused_min_exp_bits: int = 32
    # The dispatch floor for the fused ladder.  Batches in
    # [packed_min_batch, fused_min_batch) don't fill a tile on their
    # own; the kernel wrappers pad the batch up to the tile minimum
    # (kernels/common/tiling.MIN_TILE) and run the fused ladder anyway
    # -- the padded lanes ride for free on the VPU's sublane axis, so
    # one padded launch still beats ~nbits jnp-composition dispatches.
    # Below packed_min_batch even the padded launch loses to the jnp
    # ladder's lower fixed cost.
    packed_min_batch: int = 4


MODEXP_DISPATCH = ModExpDispatchConfig()


def modexp_modmul_count(exp_bits: int, window: int) -> int:
    """Modular multiplies the windowed ladder schedule performs for an
    ``exp_bits``-bit exponent at window size w, EXCLUDING the two
    Montgomery domain transforms (to_mont/from_mont; Barrett has none):

        table build           2**w - 2     (t[2..2**w-1]; t[0], t[1] free)
        first window          0            (res := table[window 0])
        remaining windows     (ceil(exp_bits/w) - 1) * (w + 1)

    Always <= exp_bits * (1 + 1/w) + 2**w, vs ~2 * exp_bits for the
    bit-serial (w=1) ladder; asserted by tests/test_modexp_window.py."""
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    nwin = -(-max(1, exp_bits) // window)
    return (1 << window) - 2 + (nwin - 1) * (window + 1)


def pick_modexp_window(exp_bits: int, cap: int | None = None) -> int:
    """Smallest-cost window size <= ``cap`` (default MODEXP_DISPATCH.
    window_bits) for an ``exp_bits``-bit exponent: argmin of
    ``modexp_modmul_count`` -- short exponents (RSA e = 65537) get small
    windows where the 2**w table build would dominate, long exponents
    saturate at the cap."""
    from repro.obs import trace as _trace

    cap = cap or MODEXP_DISPATCH.window_bits
    best, best_cost = 1, None
    for w in range(1, max(1, cap) + 1):
        cost = modexp_modmul_count(exp_bits, w)
        if best_cost is None or cost < best_cost:
            best, best_cost = w, cost
    _trace.emit("modexp_window", exp_bits, 1, str(best), "argmin_modmuls",
                cap=cap, modmuls=best_cost)
    return best


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs for serve/bignum_engine.BignumEngine (the request-level
    continuous-batching crypto server).

    ``bucket_bits`` are the modulus-width tiers the shape-bucketed jit
    cache quantizes requests into: a request for an ``nbits``-bit
    modulus runs at the smallest bucket >= nbits, so any mix of natural
    widths dispatches into a FINITE set of compiled shapes instead of
    retracing per width.  The tiers mirror the paper's evaluation grid
    (and MUL_DISPATCH's kernel ranges).  ``exp_bucket_bits`` does the
    same for raw mod_exp exponent widths (RSA keys keep their natural
    exponent width -- the key set is finite, so it's already a finite
    shape set).

    ``slots`` is the padded batch the engine flushes -- sized so the
    fused ladder runs in its MODEXP_DISPATCH.fused_min_batch regime --
    and ``max_wait_s`` bounds how long a lone request waits for
    batchmates before a deadline flush serves a partial (padded) batch.

    The fault-tolerance knobs (PR 9): ``max_queue`` is the admission
    bound -- arrivals beyond that many queued requests are SHED at
    submit (completed immediately with ``shed=True``, never silently
    dropped) so a burst degrades to bounded rejections instead of
    unbounded latency; ``max_retries`` / ``retry_backoff_s`` bound the
    retry loop a transiently-failing flush gets before the engine
    degrades that bucket to the next backend tier.
    """

    bucket_bits: Tuple[int, ...] = (
        256, 512, 1024, 2048, 4096, 8192)
    exp_bucket_bits: Tuple[int, ...] = (
        16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192)
    slots: int = 8                    # >= MODEXP_DISPATCH.fused_min_batch
    max_wait_s: float = 0.05          # deadline-flush bound per request
    max_queue: int = 1024             # admission bound (shed beyond this)
    max_retries: int = 2              # flush retries before degrading
    retry_backoff_s: float = 0.0      # base of the exponential backoff


SERVE = ServeConfig()


def quantize_bits(nbits: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= ``nbits`` (the serve engine's shape
    quantizer).  Raises when nbits overflows every tier so oversized
    requests fail loudly instead of silently retracing at a new shape."""
    if nbits < 1:
        raise ValueError(f"nbits must be >= 1, got {nbits}")
    for b in sorted(buckets):
        if nbits <= b:
            return b
    raise ValueError(
        f"operand width {nbits} bits exceeds the largest serve bucket; "
        f"choose from buckets {tuple(sorted(buckets))}")


@dataclasses.dataclass(frozen=True)
class DoTBenchConfig:
    operand_bits: Tuple[int, ...] = (
        512, 1024, 2048, 3072, 4096, 6144, 8192, 12288,
        16384, 20480, 24576, 32768)
    batch: int = 4096                 # independent operations per call
    mul_base_bits: int = 256          # base-case multiply (Table 4)
    karatsuba_threshold_digits: int = 16
    pathological_batch: int = 64
    rsa_bits: Tuple[int, ...] = (512, 1024, 2048)
    pi_digits: int = 1000


CONFIG = DoTBenchConfig()
REDUCED = DoTBenchConfig(
    operand_bits=(512, 1024), batch=64, pathological_batch=8,
    rsa_bits=(512,), pi_digits=100)
