"""smollm-135m [dense]: 30L d576 9H (GQA kv=3) d_ff=1536 vocab=49152.

[hf:HuggingFaceTB/SmolLM-135M; hf]  Llama-arch small model; also the
end-to-end training-example target (examples/train_smollm.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, vocab_size=49152, d_ff=1536,
    num_heads=9, num_kv_heads=3, head_dim=64,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="smollm-135m-reduced", num_layers=2, d_model=96, d_ff=192,
    num_heads=3, num_kv_heads=1, head_dim=32, vocab_size=256, q_chunk=64)
