"""Registry of assigned architecture configs (+ the paper's own workload).

Each module exports CONFIG (the exact published configuration) and
REDUCED (a same-family miniature for CPU smoke tests).
"""
import importlib

ARCH_IDS = (
    "granite_3_8b",
    "gemma2_2b",
    "minicpm3_4b",
    "smollm_135m",
    "dbrx_132b",
    "olmoe_1b_7b",
    "zamba2_1_2b",
    "llava_next_34b",
    "rwkv6_1_6b",
    "seamless_m4t_large_v2",
)

# canonical hyphenated ids (CLI) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str, reduced: bool = False):
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCH_IDS}
