"""minicpm3-4b [dense/MLA]: 62L d2560 40H d_ff=6400 vocab=73448.

[hf:openbmb/MiniCPM3-4B; hf]  Multi-head Latent Attention:
q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v_head 64.
Decode uses the absorbed (latent-space) form.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="mla",
    num_layers=62, d_model=2560, vocab_size=73448, d_ff=6400,
    num_heads=40, num_kv_heads=40, head_dim=96,
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="minicpm3-4b-reduced", num_layers=2, d_model=128, d_ff=256,
    num_heads=4, num_kv_heads=4, head_dim=48, vocab_size=256,
    q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, q_chunk=64)
