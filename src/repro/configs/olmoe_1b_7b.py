"""olmoe-1b-7b [moe]: 16L d2048 16H (GQA kv=16) d_ff=1024/expert
vocab=50304, 64 experts top-8.

[arXiv:2409.02060; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, vocab_size=50304, d_ff=1024,
    num_heads=16, num_kv_heads=16, head_dim=128,
    num_experts=64, top_k=8,
    remat="full",
)

REDUCED = CONFIG.replace(
    name="olmoe-1b-7b-reduced", num_layers=2, d_model=128, d_ff=64,
    num_heads=4, num_kv_heads=4, head_dim=32, vocab_size=256,
    num_experts=8, top_k=2, q_chunk=64)
