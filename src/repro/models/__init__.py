from repro.models.config import (
    SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
)
from repro.models.model import build_model
