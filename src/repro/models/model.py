"""Full-model assembly for every assigned architecture family.

Layer stacks run under ``lax.scan`` over stacked per-layer params, split
into ``segments`` (a tuple of scan lengths).  The dry-run lowers each cell
with the default segmentation and once more with one extra segment (same
total layers): the cost delta isolates one scan-body cost, which the
roofline multiplies back by the true layer count (see launch/roofline.py).

Families:
  DecoderModel : dense | moe | mla | vlm   (+ gemma2 local/global pairs)
  RWKVModel    : rwkv6 (attention-free)
  HybridModel  : zamba2 (mamba2 backbone + shared attention block)
  EncDecModel  : seamless (audio encoder stub -> text decoder)
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as dsh
from repro.models import layers as Lyr
from repro.models import ssm as S
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import Init

F32 = jnp.float32


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, fn: Callable):
    """vmap a per-layer init over n keys -> stacked (n, ...) params."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(Init(k)))(keys)


def layer_scan(body, carry, stacked, segments, remat: str = "none"):
    """Scan `body` over stacked per-layer inputs, split into segments.

    body: (carry, per_layer) -> (carry, per_layer_out)
    Returns (carry, stacked_outputs or None).
    """
    if remat == "full":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    outs = []
    start = 0
    for seg in segments:
        xs = jax.tree.map(lambda a: a[start:start + seg], stacked)
        carry, ys = jax.lax.scan(body, carry, xs)
        outs.append(ys)
        start += seg
    if outs and outs[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.concatenate(zs, 0), *outs)
    else:
        ys = None
    return carry, ys


def _positions(B, S, offset=0):
    return jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None], (B, S)) + offset


def gather_outer(params):
    """Explicit FSDP all-gather for non-scanned params (embed, head, norms,
    shared blocks); scanned layer params gather inside their scan body."""
    scanned = ("layers", "enc_layers", "dec_layers")
    sub = {k: v for k, v in params.items() if k not in scanned}
    sub = dsh.gather_params(sub)
    return {**params, **sub}


def embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens].astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * float(np.sqrt(cfg.d_model))   # python float: weak-typed
    return x


def unembed(params, x, cfg: ModelConfig):
    dt = cfg.cdtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dt))
    else:
        logits = x @ params["lm_head"].astype(dt)
    # vocab-sharded logits: the CE reductions all-reduce over "model",
    # instead of materializing (B, S, V) replicated.
    logits = dsh.constrain(logits, "dp", None, "model")
    logits = Lyr.softcap(logits.astype(F32), cfg.final_softcap)
    logits = dsh.constrain(logits, "dp", None, "model")
    vp = cfg.padded_vocab
    if vp != cfg.vocab_size:  # mask padded vocab columns
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < cfg.vocab_size, logits, -1e30)
    return logits


def ce_loss(logits, targets, mask=None):
    """logits (B,S,V) f32; targets (B,S) int32; mask (B,S) or None."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    z_loss = 1e-4 * jnp.square(lse)
    per_tok = nll + z_loss
    if mask is None:
        return per_tok.mean(), {"nll": nll.mean()}
    denom = jnp.clip(mask.sum(), 1.0)
    return (per_tok * mask).sum() / denom, {
        "nll": (nll * mask).sum() / denom}


def _norm(p, x, eps):
    return Lyr.rmsnorm(x, p, eps)


# ---------------------------------------------------------------------------
# Decoder-only model (dense / moe / mla / vlm / gemma2-pattern)
# ---------------------------------------------------------------------------

class DecoderModel:
    """Generic decoder LM.  Unit = one layer, or one (local, global) pair
    for gemma2's alternating pattern."""

    def __init__(self, cfg: ModelConfig, segments: Optional[Tuple[int, ...]] = None):
        self.cfg = cfg
        self.pair = cfg.attn_pattern == "local_global"
        assert cfg.num_layers % (2 if self.pair else 1) == 0
        self.units = cfg.num_layers // (2 if self.pair else 1)
        self.segments = tuple(segments) if segments else (self.units,)
        assert sum(self.segments) == self.units

    # -- params ------------------------------------------------------------
    def _init_sublayer(self, ini: Init, kind: str):
        cfg = self.cfg
        p = {"ln1": ini.ones((cfg.d_model,), cfg.pdtype),
             "ln2": ini.ones((cfg.d_model,), cfg.pdtype)}
        if cfg.post_norms:
            p["ln1p"] = ini.ones((cfg.d_model,), cfg.pdtype)
            p["ln2p"] = ini.ones((cfg.d_model,), cfg.pdtype)
        if cfg.family == "mla":
            p["attn"] = Lyr.init_mla(ini, cfg)
        else:
            p["attn"] = Lyr.init_attn(ini, cfg)
        if cfg.family == "moe":
            p["moe"] = Lyr.init_moe(ini, cfg)
        else:
            p["mlp"] = Lyr.init_mlp(ini, cfg)
        return p

    def _init_unit(self, ini: Init):
        if self.pair:
            return {"local": self._init_sublayer(ini, "local"),
                    "global": self._init_sublayer(ini, "global")}
        return self._init_sublayer(ini, "global")

    def init(self, key):
        cfg = self.cfg
        ini = Init(key)
        params = {
            "embed": ini.dense((cfg.padded_vocab, cfg.d_model), cfg.pdtype),
            "final_norm": ini.ones((cfg.d_model,), cfg.pdtype),
            "layers": _stack_init(ini.take(), self.units, self._init_unit),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = ini.dense(
                (cfg.d_model, cfg.padded_vocab), cfg.pdtype)
        return params

    # -- one sublayer ------------------------------------------------------
    def _sublayer(self, p, x, positions, *, window, cache, index):
        cfg = self.cfg
        h = _norm(p["ln1"], x, cfg.norm_eps)
        if cfg.family == "mla":
            a, new_cache = Lyr.mla_attention(
                p["attn"], h, positions, cfg, cache=cache, cache_index=index)
        else:
            a, new_cache = Lyr.attention(
                p["attn"], h, positions, cfg, window=window,
                cache=cache, cache_index=index)
        if cfg.post_norms:
            a = _norm(p["ln1p"], a, cfg.norm_eps)
        x = x + a
        h = _norm(p["ln2"], x, cfg.norm_eps)
        aux = jnp.zeros((), F32)
        if cfg.family == "moe":
            f, aux = Lyr.moe_ffn(p["moe"], h, cfg)
        else:
            f = Lyr.mlp(p["mlp"], h, cfg)
        if cfg.post_norms:
            f = _norm(p["ln2p"], f, cfg.norm_eps)
        return x + f, new_cache, aux

    def _unit(self, p, x, positions, cache, index):
        cfg = self.cfg
        if self.pair:
            x, c_l, a1 = self._sublayer(
                p["local"], x, positions, window=cfg.local_window,
                cache=None if cache is None else cache["local"], index=index)
            x, c_g, a2 = self._sublayer(
                p["global"], x, positions, window=None,
                cache=None if cache is None else cache["global"], index=index)
            new_cache = None if c_l is None and c_g is None else \
                {"local": c_l, "global": c_g}
            return x, new_cache, a1 + a2
        return self._sublayer(p, x, positions, window=None,
                              cache=cache, index=index)

    # -- forward -----------------------------------------------------------
    def _assemble_inputs(self, params, batch):
        """token (+image) embedding -> (x, positions)."""
        cfg = self.cfg
        x = embed_tokens(params, batch["tokens"], cfg)
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(cfg.cdtype)
            x = jnp.concatenate([img, x], axis=1)
        B, S, _ = x.shape
        return x, _positions(B, S)

    def _stack(self, params, x, positions, caches, index):
        cfg = self.cfg

        def body(carry, per_layer):
            x, aux = carry
            if caches is None:
                p = per_layer
                cache = None
            else:
                p, cache = per_layer
            p = dsh.gather_params(p)
            x, new_cache, a = self._unit(p, x, positions, cache, index)
            return (x, aux + a), new_cache

        stacked = params["layers"] if caches is None else (params["layers"], caches)
        (x, aux), new_caches = layer_scan(
            body, (x, jnp.zeros((), F32)), stacked, self.segments, cfg.remat)
        return _norm(params["final_norm"], x, cfg.norm_eps), new_caches, aux

    def loss(self, params, batch):
        cfg = self.cfg
        params = gather_outer(params)
        x, positions = self._assemble_inputs(params, batch)
        x, _, aux = self._stack(params, x, positions, None, None)
        logits = unembed(params, x, cfg)
        loss, metrics = ce_loss(logits, batch["targets"], batch.get("loss_mask"))
        loss = loss + 0.01 * aux
        metrics["aux"] = aux
        return loss, metrics

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        params = gather_outer(params)
        x, positions = self._assemble_inputs(params, batch)
        x, caches, _ = self._stack(params, x, positions, cache, None)
        logits = unembed(params, x[:, -1:], cfg)
        return logits[:, 0], caches

    def decode_step(self, params, cache, tokens, index):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, tokens, cfg)
        positions = jnp.broadcast_to(index, (x.shape[0], 1)).astype(jnp.int32)
        x, caches, _ = self._stack(params, x, positions, cache, index)
        logits = unembed(params, x, cfg)
        return logits[:, 0], caches

    # -- specs ---------------------------------------------------------------
    def _attn_cache_spec(self, B, S):
        cfg = self.cfg
        if cfg.family == "mla":
            return {
                "ckv": jax.ShapeDtypeStruct((B, S, cfg.kv_lora_rank), cfg.cdtype),
                "k_rope": jax.ShapeDtypeStruct((B, S, cfg.qk_rope_dim), cfg.cdtype),
            }
        K, hd = cfg.num_kv_heads, cfg.head_dim
        return {"k": jax.ShapeDtypeStruct((B, S, K, hd), cfg.cdtype),
                "v": jax.ShapeDtypeStruct((B, S, K, hd), cfg.cdtype)}

    def cache_specs(self, B, S):
        # NOTE: the local cache is allocated at full S (a ring buffer of
        # size `local_window` is the memory-optimal layout; recorded as a
        # hillclimb candidate in EXPERIMENTS.md SS Perf).
        unit = (
            {"local": self._attn_cache_spec(B, S),
             "global": self._attn_cache_spec(B, S)}
            if self.pair else self._attn_cache_spec(B, S))
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.units,) + s.shape, s.dtype), unit)

    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B = shape.global_batch
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        S = shape.seq_len
        sp = {}
        if cfg.family == "vlm":
            n_img = cfg.num_image_tokens
            sp["image_embeds"] = jax.ShapeDtypeStruct(
                (B, n_img, cfg.d_model), cfg.cdtype)
            sp["tokens"] = jax.ShapeDtypeStruct((B, S - n_img), jnp.int32)
        else:
            sp["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            sp["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            if cfg.family == "vlm":
                sp["loss_mask"] = jax.ShapeDtypeStruct((B, S), F32)
        return sp

    def scan_info(self):
        return {"layers": (self.units, (self.units,))}


# ---------------------------------------------------------------------------
# RWKV6 model (attention-free)
# ---------------------------------------------------------------------------

class RWKVModel:
    def __init__(self, cfg: ModelConfig, segments=None):
        self.cfg = cfg
        self.units = cfg.num_layers
        self.segments = tuple(segments) if segments else (self.units,)
        assert sum(self.segments) == self.units

    def _init_unit(self, ini: Init):
        cfg = self.cfg
        p = S.init_rwkv6(ini, cfg)
        p["ln1"] = ini.ones((cfg.d_model,), cfg.pdtype)
        p["ln2"] = ini.ones((cfg.d_model,), cfg.pdtype)
        return p

    def init(self, key):
        cfg = self.cfg
        ini = Init(key)
        params = {
            "embed": ini.dense((cfg.padded_vocab, cfg.d_model), cfg.pdtype),
            "final_norm": ini.ones((cfg.d_model,), cfg.pdtype),
            "layers": _stack_init(ini.take(), self.units, self._init_unit),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = ini.dense(
                (cfg.d_model, cfg.padded_vocab), cfg.pdtype)
        return params

    def _stack(self, params, x, states, decode: bool):
        cfg = self.cfg

        def body(x, per_layer):
            p, st = per_layer if states is not None else (per_layer, None)
            p = dsh.gather_params(p)
            h = _norm(p["ln1"], x, cfg.norm_eps)
            tm_state = None if st is None else {"S": st["S"], "last": st["last_tm"]}
            if decode:
                y, tm_new = S.rwkv6_time_mix_decode(p["tm"], h, cfg, tm_state)
            else:
                y, tm_new = S.rwkv6_time_mix(p["tm"], h, cfg, tm_state)
            x = x + y
            h = _norm(p["ln2"], x, cfg.norm_eps)
            y, cm_last = S.rwkv6_channel_mix(
                p["cm"], h, cfg, None if st is None else st["last_cm"])
            x = x + y
            new_st = None if st is None else {
                "S": tm_new["S"], "last_tm": tm_new["last"], "last_cm": cm_last}
            return x, new_st

        stacked = params["layers"] if states is None else (params["layers"], states)
        x, new_states = layer_scan(body, x, stacked, self.segments, cfg.remat)
        return _norm(params["final_norm"], x, cfg.norm_eps), new_states

    def loss(self, params, batch):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, batch["tokens"], cfg)
        x, _ = self._stack(params, x, None, False)
        logits = unembed(params, x, cfg)
        return ce_loss(logits, batch["targets"], batch.get("loss_mask"))

    def prefill(self, params, batch, states):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, batch["tokens"], cfg)
        x, new_states = self._stack(params, x, states, False)
        logits = unembed(params, x[:, -1:], cfg)
        return logits[:, 0], new_states

    def decode_step(self, params, states, tokens, index):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, tokens, cfg)
        x, new_states = self._stack(params, x, states, True)
        logits = unembed(params, x, cfg)
        return logits[:, 0], new_states

    def cache_specs(self, B, S):
        u = {
            "S": jax.ShapeDtypeStruct((B, self.cfg.rwkv_heads,
                                       self.cfg.rwkv_head_dim,
                                       self.cfg.rwkv_head_dim), F32),
            "last_tm": jax.ShapeDtypeStruct((B, self.cfg.d_model), self.cfg.cdtype),
            "last_cm": jax.ShapeDtypeStruct((B, self.cfg.d_model), self.cfg.cdtype),
        }
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.units,) + s.shape, s.dtype), u)

    def input_specs(self, shape: ShapeConfig):
        B = shape.global_batch
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        sp = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}
        if shape.kind == "train":
            sp["targets"] = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
        return sp

    def scan_info(self):
        return {"layers": (self.units, (self.units,))}


# ---------------------------------------------------------------------------
# Hybrid model (zamba2: mamba2 backbone + shared attention block)
# ---------------------------------------------------------------------------

class HybridModel:
    """Mamba2 layers in groups of `shared_attn_every`, with ONE weight-shared
    attention block applied between groups (input = concat(hidden, embeds))."""

    def __init__(self, cfg: ModelConfig, segments=None):
        self.cfg = cfg
        self.units = cfg.num_layers
        k = cfg.shared_attn_every
        if segments is None:
            segs, rem = [], cfg.num_layers
            while rem > 0:
                segs.append(min(k, rem))
                rem -= min(k, rem)
            segments = tuple(segs)
        self.segments = tuple(segments)
        assert sum(self.segments) == self.units
        # shared block applied after every FULL group except the last segment
        self.n_shared = max(1, (cfg.num_layers - 1) // k)

    def _init_unit(self, ini: Init):
        cfg = self.cfg
        p = {"mamba": S.init_mamba2(ini, cfg),
             "ln": ini.ones((cfg.d_model,), cfg.pdtype)}
        return p

    def init(self, key):
        cfg = self.cfg
        ini = Init(key)
        d = cfg.d_model
        shared = {
            "proj": ini.dense((2 * d, d), cfg.pdtype),
            "ln1": ini.ones((d,), cfg.pdtype),
            "ln2": ini.ones((d,), cfg.pdtype),
            "attn": Lyr.init_attn(ini, cfg),
            "mlp": Lyr.init_mlp(ini, cfg),
        }
        return {
            "embed": ini.dense((cfg.padded_vocab, d), cfg.pdtype),
            "final_norm": ini.ones((d,), cfg.pdtype),
            "shared": shared,
            "layers": _stack_init(ini.take(), self.units, self._init_unit),
        }

    def _shared_block(self, p, x, x0, positions, cache, index):
        cfg = self.cfg
        h = jnp.concatenate([x, x0], axis=-1) @ p["proj"].astype(cfg.cdtype)
        a, new_cache = Lyr.attention(
            p["attn"], _norm(p["ln1"], h, cfg.norm_eps), positions, cfg,
            cache=cache, cache_index=index)
        h = h + a
        h = h + Lyr.mlp(p["mlp"], _norm(p["ln2"], h, cfg.norm_eps), cfg)
        return x + h, new_cache

    def _forward(self, params, x, positions, caches, index, decode: bool):
        cfg = self.cfg
        x0 = x
        mamba_states = None if caches is None else caches["mamba"]
        kv_caches = None if caches is None else caches["shared_kv"]

        def body(x, per_layer):
            p, st = per_layer if mamba_states is not None else (per_layer, None)
            p = dsh.gather_params(p)
            h = _norm(p["ln"], x, cfg.norm_eps)
            if decode:
                y, new_st = S.mamba2_decode(p["mamba"], h, cfg, st)
            else:
                y, new_st = S.mamba2_mix(p["mamba"], h, cfg, st)
            return x + y, new_st

        new_states, new_kv = [], []
        start = 0
        for gi, seg in enumerate(self.segments):
            stacked = jax.tree.map(lambda a: a[start:start + seg],
                                   params["layers"] if mamba_states is None
                                   else (params["layers"], mamba_states))
            b = body
            if cfg.remat == "full" and not decode:
                b = jax.checkpoint(
                    b, policy=jax.checkpoint_policies.nothing_saveable)
            x, ys = jax.lax.scan(b, x, stacked)
            new_states.append(ys)
            start += seg
            if gi < self.n_shared:
                kv = None if kv_caches is None else kv_caches[gi]
                x, nkv = self._shared_block(
                    params["shared"], x, x0, positions, kv, index)
                new_kv.append(nkv)
        x = _norm(params["final_norm"], x, cfg.norm_eps)
        if caches is None:
            return x, None
        new_states = jax.tree.map(lambda *zs: jnp.concatenate(zs, 0),
                                  *new_states)
        return x, {"mamba": new_states, "shared_kv": new_kv}

    def loss(self, params, batch):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, batch["tokens"], cfg)
        B, Sq, _ = x.shape
        x, _ = self._forward(params, x, _positions(B, Sq), None, None, False)
        logits = unembed(params, x, cfg)
        return ce_loss(logits, batch["targets"], batch.get("loss_mask"))

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, batch["tokens"], cfg)
        B, Sq, _ = x.shape
        x, caches = self._forward(params, x, _positions(B, Sq), caches, None, False)
        logits = unembed(params, x[:, -1:], cfg)
        return logits[:, 0], caches

    def decode_step(self, params, caches, tokens, index):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, tokens, cfg)
        positions = jnp.broadcast_to(index, (x.shape[0], 1)).astype(jnp.int32)
        x, caches = self._forward(params, x, positions, caches, index, True)
        logits = unembed(params, x, cfg)
        return logits[:, 0], caches

    def cache_specs(self, B, Scache):
        cfg = self.cfg
        st = S.mamba2_state_specs(cfg, B)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.units,) + s.shape, s.dtype), st)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        kv = [{"k": jax.ShapeDtypeStruct((B, Scache, K, hd), cfg.cdtype),
               "v": jax.ShapeDtypeStruct((B, Scache, K, hd), cfg.cdtype)}
              for _ in range(self.n_shared)]
        return {"mamba": stacked, "shared_kv": kv}

    def input_specs(self, shape: ShapeConfig):
        B = shape.global_batch
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        sp = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}
        if shape.kind == "train":
            sp["targets"] = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
        return sp

    def scan_info(self):
        return {"layers": (self.units, self.segments)}


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless: audio frontend stub -> text decoder)
# ---------------------------------------------------------------------------

class EncDecModel:
    def __init__(self, cfg: ModelConfig, segments=None):
        self.cfg = cfg
        self.enc_units = cfg.enc_layers
        self.dec_units = cfg.dec_layers
        segments = segments or {}
        self.enc_segments = tuple(segments.get("enc", (self.enc_units,)))
        self.dec_segments = tuple(segments.get("dec", (self.dec_units,)))

    def _init_enc_unit(self, ini: Init):
        cfg = self.cfg
        return {"ln1": ini.ones((cfg.d_model,), cfg.pdtype),
                "ln2": ini.ones((cfg.d_model,), cfg.pdtype),
                "attn": Lyr.init_attn(ini, cfg),
                "mlp": Lyr.init_mlp(ini, cfg)}

    def _init_dec_unit(self, ini: Init):
        cfg = self.cfg
        return {"ln1": ini.ones((cfg.d_model,), cfg.pdtype),
                "ln2": ini.ones((cfg.d_model,), cfg.pdtype),
                "ln3": ini.ones((cfg.d_model,), cfg.pdtype),
                "attn": Lyr.init_attn(ini, cfg),
                "xattn": Lyr.init_cross_attn(ini, cfg),
                "mlp": Lyr.init_mlp(ini, cfg)}

    def init(self, key):
        cfg = self.cfg
        ini = Init(key)
        params = {
            "embed": ini.dense((cfg.padded_vocab, cfg.d_model), cfg.pdtype),
            "enc_norm": ini.ones((cfg.d_model,), cfg.pdtype),
            "final_norm": ini.ones((cfg.d_model,), cfg.pdtype),
            "enc_layers": _stack_init(ini.take(), self.enc_units,
                                      self._init_enc_unit),
            "dec_layers": _stack_init(ini.take(), self.dec_units,
                                      self._init_dec_unit),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = ini.dense(
                (cfg.d_model, cfg.padded_vocab), cfg.pdtype)
        return params

    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(cfg.cdtype)
        B, T, _ = x.shape
        pos = _positions(B, T)

        def body(x, p):
            p = dsh.gather_params(p)
            h = _norm(p["ln1"], x, cfg.norm_eps)
            a, _ = Lyr.attention(p["attn"], h, pos, cfg, causal=False)
            x = x + a
            h = _norm(p["ln2"], x, cfg.norm_eps)
            return x + Lyr.mlp(p["mlp"], h, cfg), None

        x, _ = layer_scan(body, x, params["enc_layers"], self.enc_segments,
                          cfg.remat)
        return _norm(params["enc_norm"], x, cfg.norm_eps)

    def _decode_stack(self, params, x, enc_out, positions, caches, index):
        cfg = self.cfg

        def body(x, per_layer):
            p, cache = per_layer if caches is not None else (per_layer, None)
            p = dsh.gather_params(p)
            h = _norm(p["ln1"], x, cfg.norm_eps)
            a, new_cache = Lyr.attention(p["attn"], h, positions, cfg,
                                         cache=cache, cache_index=index)
            x = x + a
            h = _norm(p["ln2"], x, cfg.norm_eps)
            x = x + Lyr.cross_attention(p["xattn"], h, enc_out, cfg)
            h = _norm(p["ln3"], x, cfg.norm_eps)
            return x + Lyr.mlp(p["mlp"], h, cfg), new_cache

        stacked = params["dec_layers"] if caches is None else \
            (params["dec_layers"], caches)
        x, new_caches = layer_scan(body, x, stacked, self.dec_segments,
                                   cfg.remat)
        return _norm(params["final_norm"], x, cfg.norm_eps), new_caches

    def loss(self, params, batch):
        cfg = self.cfg
        params = gather_outer(params)
        enc_out = self._encode(params, batch["audio_frames"])
        x = embed_tokens(params, batch["tokens"], cfg)
        B, Sq, _ = x.shape
        x, _ = self._decode_stack(params, x, enc_out, _positions(B, Sq),
                                  None, None)
        logits = unembed(params, x, cfg)
        return ce_loss(logits, batch["targets"], batch.get("loss_mask"))

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        params = gather_outer(params)
        enc_out = self._encode(params, batch["audio_frames"])
        x = embed_tokens(params, batch["tokens"], cfg)
        B, Sq, _ = x.shape
        x, kv = self._decode_stack(params, x, enc_out,
                                   _positions(B, Sq), caches["self_kv"], None)
        logits = unembed(params, x[:, -1:], cfg)
        return logits[:, 0], {"self_kv": kv, "enc_out": enc_out}

    def decode_step(self, params, caches, tokens, index):
        cfg = self.cfg
        params = gather_outer(params)
        x = embed_tokens(params, tokens, cfg)
        positions = jnp.broadcast_to(index, (x.shape[0], 1)).astype(jnp.int32)
        x, kv = self._decode_stack(params, x, caches["enc_out"], positions,
                                   caches["self_kv"], index)
        logits = unembed(params, x, cfg)
        return logits[:, 0], {"self_kv": kv, "enc_out": caches["enc_out"]}

    def cache_specs(self, B, Scache):
        cfg = self.cfg
        K, hd = cfg.num_kv_heads, cfg.head_dim
        unit = {"k": jax.ShapeDtypeStruct((B, Scache, K, hd), cfg.cdtype),
                "v": jax.ShapeDtypeStruct((B, Scache, K, hd), cfg.cdtype)}
        kv = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.dec_units,) + s.shape, s.dtype),
            unit)
        Te = Scache // cfg.enc_frames_ratio
        return {"self_kv": kv,
                "enc_out": jax.ShapeDtypeStruct((B, Te, cfg.d_model), cfg.cdtype)}

    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B = shape.global_batch
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        S_ = shape.seq_len
        Te = S_ // cfg.enc_frames_ratio
        sp = {"audio_frames": jax.ShapeDtypeStruct((B, Te, cfg.d_model), cfg.cdtype),
              "tokens": jax.ShapeDtypeStruct((B, S_), jnp.int32)}
        if shape.kind == "train":
            sp["targets"] = jax.ShapeDtypeStruct((B, S_), jnp.int32)
        return sp

    def scan_info(self):
        return {"enc": (self.enc_units, self.enc_segments),
                "dec": (self.dec_units, self.dec_segments)}


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig, segments=None):
    if cfg.family in ("dense", "moe", "mla", "vlm"):
        return DecoderModel(cfg, segments)
    if cfg.family == "ssm":
        return RWKVModel(cfg, segments)
    if cfg.family == "hybrid":
        return HybridModel(cfg, segments)
    if cfg.family == "audio":
        return EncDecModel(cfg, segments)
    raise ValueError(f"unknown family {cfg.family}")
