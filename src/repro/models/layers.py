"""Shared transformer layers (functional; params are nested dicts).

Design notes (these matter for the dry-run/roofline methodology):
  * Heavy FLOPs never live inside sequential loops: attention uses
    statically-unrolled query chunks (flash-style blocking with honest
    causal FLOPs via sliced key ranges) so ``compiled.cost_analysis()``
    sees every matmul.  Layer stacks are scanned (see model.py) and
    corrected analytically.
  * Softmax/norms in f32; matmul inputs in cfg.dtype (bf16 by default).
  * KV caches are allocated by the caller at S_max and written at
    ``index`` (decode) or ``[0:S)`` (prefill).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as dsh
from repro.models.config import ModelConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

class Init:
    """Sequential key splitter + initializers."""

    def __init__(self, key):
        self.key = key

    def take(self):
        self.key, k = jax.random.split(self.key)
        return k

    def dense(self, shape, dtype, scale: float = 0.02):
        return (jax.random.normal(self.take(), shape, F32) * scale).astype(dtype)

    def zeros(self, shape, dtype):
        return jnp.zeros(shape, dtype)

    def ones(self, shape, dtype):
        return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms / RoPE / softcap
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rope_cos_sin(positions, dim: int, theta: float, dtype):
    """positions (..., S) -> cos/sin (..., S, dim//2)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / dim))
    ang = positions[..., None].astype(F32) * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Attention core: statically chunked queries, optional local window,
# softcap, KV cache.  k/v arrive as (B, T, K, hd); q as (B, S, H, hd).
# ---------------------------------------------------------------------------

def attn_core(q, k, v, q_positions, k_positions, *, causal: bool,
              window: Optional[int], cap: Optional[float], q_chunk: int,
              k_valid_len=None):
    """Blocked GQA attention with honest causal FLOPs.

    q (B,S,H,hd); k,v (B,T,K,hd) with H = K * groups -- the grouped
    einsum contracts against the raw KV (no jnp.repeat materialization:
    repeating kv GROUPS-plicates cache reads, the dominant byte stream of
    decode; SSPerf cell 3, iteration 5).
    k_valid_len: optional traced scalar: keys at position > k_valid_len
    are masked (decode with a partially-filled cache).
    Returns (B,S,H,hd).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / np.sqrt(hd)
    nchunks = max(1, -(-S // q_chunk))
    qc = -(-S // nchunks)
    outs = []
    for i in range(nchunks):
        lo, hi_ = i * qc, min(S, (i + 1) * qc)
        qi = qg[:, lo:hi_]
        if outs:
            # serialize chunks: ties chunk i to chunk i-1's output so the
            # scheduler can reuse the (large, f32) score buffers.  Pure
            # scheduling edge; chunks stay mathematically independent.
            qi, _ = jax.lax.optimization_barrier((qi, outs[-1]))
        pq = q_positions[:, lo:hi_]
        # static key range for this chunk (honest causal/local FLOPs):
        if causal and S == T:
            k_hi = hi_
        else:
            k_hi = T
        k_lo = 0
        if window is not None and causal and S == T:
            k_lo = max(0, lo - window)
        ki = k[:, k_lo:k_hi]
        vi = v[:, k_lo:k_hi]
        pk = k_positions[:, k_lo:k_hi]
        logits = jnp.einsum("bskgd,btkd->bkgst", qi, ki,
                            preferred_element_type=F32) * scale
        logits = softcap(logits, cap)
        mask = jnp.ones((B, 1, 1, hi_ - lo, k_hi - k_lo), bool)
        if causal:
            mask &= (pk[:, None, None, None, :] <= pq[:, None, None, :, None])
        if window is not None:
            mask &= (pq[:, None, None, :, None] -
                     pk[:, None, None, None, :] < window)
        if k_valid_len is not None:
            mask &= (pk[:, None, None, None, :] <= k_valid_len)
        logits = jnp.where(mask, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        outs.append(jnp.einsum("bkgst,btkd->bskgd", w, vi))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(B, S, H, v.shape[-1])   # v head dim may differ (MLA)


def init_attn(ini: Init, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    H, K, hd = cfg.q_heads, cfg.num_kv_heads, cfg.head_dim
    pd = cfg.pdtype
    return {
        "wq": ini.dense((d, H * hd), pd),
        "wk": ini.dense((d, K * hd), pd),
        "wv": ini.dense((d, K * hd), pd),
        "wo": ini.dense((H * hd, d), pd),
    }


def attention(p, x, positions, cfg: ModelConfig, *, window=None,
              cache=None, cache_index=None, causal: bool = True):
    """GQA attention. Returns (out, new_cache).

    cache: None (training, no cache) or dict(k=(B,Smax,K,hd), v=...) with
    prefill writing [0:S) and decode writing at cache_index.
    """
    B, S, D = x.shape
    H, K, hd = cfg.q_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.cdtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, K, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, K, hd)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta, dt)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    k_valid = None
    if cache is None:
        kk, vv = k, v
        k_pos = positions
        new_cache = None
    else:
        if cache_index is None:  # prefill into cache
            kk = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
            vv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
            new_cache = {"k": kk, "v": vv}
            kk, vv = k, v                      # attend only over fresh keys
            k_pos = positions
        else:  # decode: S == 1
            # masked-select write instead of dynamic_update_slice: updating
            # a traced index on a SHARDED seq dim makes GSPMD all-gather
            # the whole cache; the elementwise select shards trivially
            # (SSPerf cell 3, iteration 3).
            T = cache["k"].shape[1]
            sel = (jnp.arange(T, dtype=jnp.int32) == cache_index)[None, :, None, None]
            kk = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
            vv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
            new_cache = {"k": kk, "v": vv}
            k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
            k_valid = cache_index

    out = attn_core(q, kk, vv, positions, k_pos, causal=causal,
                    window=window, cap=cfg.attn_softcap, q_chunk=cfg.q_chunk,
                    k_valid_len=k_valid)
    out = out.reshape(B, S, H * hd) @ p["wo"].astype(dt)
    return out, new_cache


def init_cross_attn(ini: Init, cfg: ModelConfig):
    d = cfg.d_model
    H, hd = cfg.q_heads, cfg.head_dim
    pd = cfg.pdtype
    return {
        "wq": ini.dense((d, H * hd), pd),
        "wk": ini.dense((d, H * hd), pd),
        "wv": ini.dense((d, H * hd), pd),
        "wo": ini.dense((H * hd, d), pd),
    }


def cross_attention(p, x, enc_out, cfg: ModelConfig):
    """Full (non-causal) attention over encoder output (B,Te,D)."""
    B, S, D = x.shape
    Te = enc_out.shape[1]
    H, hd = cfg.q_heads, cfg.head_dim
    dt = cfg.cdtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, Te, H, hd)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, Te, H, hd)
    pq = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    pk = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))
    out = attn_core(q, k, v, pq, pk, causal=False, window=None,
                    cap=None, q_chunk=cfg.q_chunk)
    return out.reshape(B, S, H * hd) @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-style latent compression).
# Cache holds the compressed latent (B, Smax, kv_lora) + shared rope key
# (B, Smax, rope_dim); decode uses the absorbed form (scores in latent
# space) so per-step work is O(T * kv_lora), not O(T * H * hd).
# ---------------------------------------------------------------------------

def init_mla(ini: Init, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pd = cfg.pdtype
    return {
        "wdq": ini.dense((d, qr), pd),
        "q_norm": ini.ones((qr,), pd),
        "wuq": ini.dense((qr, H * (nd + rd)), pd),
        "wdkv": ini.dense((d, kvr + rd), pd),
        "kv_norm": ini.ones((kvr,), pd),
        "wukv": ini.dense((kvr, H * (nd + vd)), pd),
        "wo": ini.dense((H * vd, d), pd),
    }


def mla_attention(p, x, positions, cfg: ModelConfig, *, cache=None,
                  cache_index=None):
    B, S, D = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    dt = cfg.cdtype

    q_lat = rmsnorm(x @ p["wdq"].astype(dt), p["q_norm"], cfg.norm_eps)
    q = (q_lat @ p["wuq"].astype(dt)).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    dkv = x @ p["wdkv"].astype(dt)
    ckv = rmsnorm(dkv[..., :kvr], p["kv_norm"], cfg.norm_eps)   # (B,S,kvr)
    k_rope = dkv[..., kvr:].reshape(B, S, 1, rd)

    cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta, dt)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    scale = 1.0 / np.sqrt(nd + rd)
    wukv = p["wukv"].astype(dt).reshape(kvr, H, nd + vd)
    w_uk, w_uv = wukv[..., :nd], wukv[..., nd:]

    if cache is not None and cache_index is not None:
        # absorbed decode: q_nope folded through w_uk into latent space.
        # masked-select writes (see attention(): sharded-dim dus pitfall).
        T = cache["ckv"].shape[1]
        sel = (jnp.arange(T, dtype=jnp.int32) == cache_index)[None, :, None]
        ckv_c = jnp.where(sel, ckv.astype(cache["ckv"].dtype), cache["ckv"])
        kr_c = jnp.where(sel, k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
                         cache["k_rope"])
        new_cache = {"ckv": ckv_c, "k_rope": kr_c}
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)       # (B,1,H,kvr)
        logits = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_c,
                             preferred_element_type=F32)
                  + jnp.einsum("bshr,btr->bhst", q_rope, kr_c,
                               preferred_element_type=F32)) * scale
        pk = jnp.arange(T, dtype=jnp.int32)
        mask = pk[None, None, None, :] <= cache_index
        logits = jnp.where(mask, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(dt)
        out_lat = jnp.einsum("bhst,btr->bshr", w, ckv_c)
        out = jnp.einsum("bshr,rhv->bshv", out_lat, w_uv)
    else:
        # train/prefill: expand k, v per head.
        kv = jnp.einsum("btr,rhn->bthn", ckv, jnp.concatenate([w_uk, w_uv], -1))
        k_nope, v = kv[..., :nd], kv[..., nd:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attn_core(qq, k, v, positions, positions, causal=True,
                        window=None, cap=None, q_chunk=cfg.q_chunk)
        if cache is not None:  # prefill: store compressed latents
            ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, 0, 0))
            kr_c = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope[:, :, 0, :], (0, 0, 0))
            new_cache = {"ckv": ckv_c, "k_rope": kr_c}
        else:
            new_cache = None
    out = out.reshape(B, S, H * vd) @ p["wo"].astype(dt)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (dense) and MoE (top-k routing with capacity dispatch).
# ---------------------------------------------------------------------------

def init_mlp(ini: Init, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    pd = cfg.pdtype
    return {
        "wg": ini.dense((d, cfg.d_ff), pd),
        "wu": ini.dense((d, cfg.d_ff), pd),
        "wd": ini.dense((cfg.d_ff, d), pd),
    }


def mlp(p, x, cfg: ModelConfig):
    dt = cfg.cdtype
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    return h @ p["wd"].astype(dt)


def init_moe(ini: Init, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = cfg.pdtype
    return {
        "router": ini.dense((d, e), pd),
        "wg": ini.dense((e, d, f), pd),
        "wu": ini.dense((e, d, f), pd),
        "wd": ini.dense((e, f, d), pd),
    }


def moe_ffn(p, x, cfg: ModelConfig):
    """Top-k MoE with PER-ROW capacity dispatch (token dropping).

    Sharding rationale (measured in EXPERIMENTS.md SSPerf, iteration 1):
    a single global dispatch needs a cumsum over ALL tokens, which the
    SPMD partitioner cannot shard -- it replicates the whole MoE on every
    chip (~500x flops).  Dispatch positions computed independently PER
    BATCH ROW keep every op batch-local: the (B, E, C_row, d) buffers
    shard over (dp, model) and expert compute is a clean batched einsum.
    Capacity is enforced per row (standard per-group capacity semantics).
    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    dt = cfg.cdtype

    logits = (x @ p["router"].astype(dt)).astype(F32)           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (B, S, K)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalize

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=F32)          # (B,S,K,E)
    ce = onehot_e.mean(axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    C = max(1, int(np.ceil(S * K / E * cfg.capacity_factor)))

    # sort-based, GATHER-only dispatch (no scatters: batched scatters with
    # explicit index arrays defeat GSPMD batching; take_along_axis gathers
    # shard cleanly over the dp batch dim -- SSPerf iteration 3).
    flat_e = expert_idx.reshape(B, S * K)                        # (B, S*K)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)          # by expert
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts                 # (B, E)
    # slot (e, c) <- sorted position starts[e] + c  (valid while c < count)
    c_idx = jnp.arange(C)
    slot_src = jnp.clip(starts[..., None] + c_idx, 0, S * K - 1)  # (B,E,C)
    valid = (c_idx[None, None, :] < counts[..., None])
    gather_slot = jnp.take_along_axis(
        sort_idx, slot_src.reshape(B, E * C), axis=1)            # (B, E*C)
    gather_tok = gather_slot // K                                # token ids
    buf = jnp.take_along_axis(x, gather_tok[..., None], axis=1)  # (B,E*C,D)
    buf = buf * valid.reshape(B, E * C, 1).astype(dt)
    buf = buf.reshape(B, E, C, D)
    buf = dsh.constrain(buf, "dp", None, None, None)

    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dt))) * \
        jnp.einsum("becd,edf->becf", buf, p["wu"].astype(dt))
    h = dsh.constrain(h, "dp", None, None, "model")
    out_buf = jnp.einsum("becf,efd->becd", h, p["wd"].astype(dt))
    # NOT constrained: the partitioner may keep out_buf as partial sums and
    # place the model-axis reduction after the (linear) combine gather,
    # shrinking the all-reduce from (B,E,C,D) to (B,S,D).

    # combine: rank of each (token, slot) within its expert = inverse sort
    inv = jnp.argsort(sort_idx, axis=1)                          # (B, S*K)
    pos = inv - jnp.take_along_axis(starts, flat_e, axis=1)
    keep = pos < C
    idx_ec = flat_e * C + jnp.clip(pos, 0, C - 1)
    y = jnp.take_along_axis(out_buf.reshape(B, E * C, D),
                            idx_ec[..., None], axis=1)           # (B,S*K,D)
    y = y * (keep[..., None].astype(dt) *
             gate_vals.reshape(B, S * K)[..., None].astype(dt))
    out = y.reshape(B, S, K, D).sum(axis=2)                      # no scatter
    out = dsh.constrain(out, "dp", None, None)
    return out, aux
