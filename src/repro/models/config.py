"""Model configuration for all assigned architectures.

One dataclass covers the ten assigned families (dense / MoE / MLA / hybrid /
SSM / VLM / audio enc-dec); family-specific fields are ignored elsewhere.
Each src/repro/configs/<arch>.py instantiates this with the exact published
numbers and a reduced twin for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | mla | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    d_ff: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    attn_pattern: str = "global"     # "global" | "local_global" (gemma2)
    local_window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    post_norms: bool = False         # gemma2-style post-block norms
    # mlp
    mlp_act: str = "silu"            # silu | gelu
    # moe
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # mla (minicpm3 / deepseek style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 32
    # hybrid (zamba2): shared attention block every k mamba layers
    shared_attn_every: int = 0
    # enc-dec (seamless)
    enc_layers: int = 0
    dec_layers: int = 0
    enc_frames_ratio: int = 4        # encoder frames = seq_len // ratio
    # vlm
    num_image_tokens: int = 0
    # numerics / embedding
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    # sharding/infra knobs
    pad_heads_to: int = 0            # pad q heads for TP divisibility (llava)
    remat: str = "none"              # none | full | dots
    q_chunk: int = 4096              # unrolled flash-style query chunking
    # roofline bookkeeping
    sub_quadratic: bool = False      # True -> long_500k cell applies

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 128) * 128

    @property
    def q_heads(self) -> int:
        """Q heads after optional TP padding."""
        return self.pad_heads_to or self.num_heads

    @property
    def kv_groups(self) -> int:
        return max(1, self.q_heads // max(1, self.num_kv_heads))

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig):
    """long_500k only applies to sub-quadratic archs (SSM/hybrid/linear-attn);
    pure full-attention archs skip it (recorded in DESIGN.md)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return tuple(out)
