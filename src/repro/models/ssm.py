"""State-space / linear-attention sequence mixers: Mamba2 (SSD) and RWKV6.

Both use the chunked formulation: within-chunk work is batched matmuls
(parallel over chunks -> full FLOP visibility for the roofline), and only a
tiny cross-chunk state stitch runs under lax.scan.  Decode is a single-step
state update (O(1) memory -- the reason these archs own the long_500k cell).

Numerical notes:
  * Mamba2 decays: dA = dt * A <= 0, and every exponent is a difference
    cs_t - cs_s with t >= s, hence <= 0: stable by construction.
  * RWKV6 per-channel data-dependent decay (the "Finch" hallmark) uses the
    factored intra-chunk form r*exp(cs_prev) / k*exp(-cs); the per-step
    log-decay is clamped to [-RWKV_MAX_DECAY, -1e-6] so exp(|cs|) stays
    within f32 over a chunk (DESIGN.md records this deviation; a log-domain
    Pallas kernel would remove it).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import Init, rmsnorm

F32 = jnp.float32
RWKV_MAX_DECAY = 2.5   # max -log(w) per step; 32-step chunk => exp(80) < f32 max


# ---------------------------------------------------------------------------
# Mamba2 (SSD, single B/C group)
# ---------------------------------------------------------------------------

def init_mamba2(ini: Init, cfg: ModelConfig):
    """Projections are split per component (z/x/B/C/dt) instead of one
    concatenated in_proj: slicing a TP-sharded concat dim crosses shard
    boundaries, while separate weights shard cleanly on their own dims."""
    d, di = cfg.d_model, cfg.ssm_inner
    ds, nh, ck = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    pd = cfg.pdtype
    return {
        "wz": ini.dense((d, di), pd),
        "wx": ini.dense((d, di), pd),
        "wB": ini.dense((d, ds), pd),
        "wC": ini.dense((d, ds), pd),
        "wdt": ini.dense((d, nh), pd),
        "conv_w": ini.dense((ck, di + 2 * ds), pd, scale=0.5),
        "conv_b": ini.zeros((di + 2 * ds,), pd),
        "A_log": ini.dense((nh,), pd, scale=1.0),
        "D": ini.ones((nh,), pd),
        "dt_bias": ini.zeros((nh,), pd),
        "norm": ini.ones((di,), pd),
        "out_proj": ini.dense((di, d), pd),
    }


def _causal_conv(xBC, w, b, tail=None):
    """Depthwise causal conv via static shifts.  xBC (B,S,C); w (ck,C).

    tail: (B, ck-1, C) previous inputs (decode/chunk continuation) or None.
    Returns (out (B,S,C), new_tail (B, ck-1, C)).
    """
    B, S, C = xBC.shape
    ck = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, ck - 1, C), xBC.dtype)
    ext = jnp.concatenate([tail, xBC], axis=1)          # (B, S+ck-1, C)
    out = jnp.zeros((B, S, C), xBC.dtype)
    for j in range(ck):
        out = out + ext[:, j: j + S] * w[j]
    new_tail = ext[:, -(ck - 1):] if ck > 1 else tail
    return jax.nn.silu(out + b), new_tail


def _project(p, x, dt_):
    """x (B,S,D) -> z (B,S,di), xBC (B,S,di+2ds), dt (B,S,nh)."""
    z = x @ p["wz"].astype(dt_)
    xc = x @ p["wx"].astype(dt_)
    Bv = x @ p["wB"].astype(dt_)
    Cv = x @ p["wC"].astype(dt_)
    dt = x @ p["wdt"].astype(dt_)
    return z, jnp.concatenate([xc, Bv, Cv], axis=-1), dt


def mamba2_mix(p, x, cfg: ModelConfig, state=None):
    """Training/prefill path (chunked SSD).  x (B,S,D).

    state: None or {"h": (B,nh,hp,ds), "conv": (B,ck-1,di+2ds)}.
    Returns (y (B,S,D), new_state).
    """
    B, S, D = x.shape
    di, ds = cfg.ssm_inner, cfg.ssm_state
    nh, hp, ck = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    dt_ = cfg.cdtype
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} must be divisible by ssm_chunk {Q}"
    NC = S // Q

    z, xBC, dt = _project(p, x, dt_)
    tail = state["conv"] if state is not None else None
    xBC, new_tail = _causal_conv(xBC, p["conv_w"].astype(dt_),
                                 p["conv_b"].astype(dt_), tail)
    xc = xBC[..., :di]
    Bv = xBC[..., di: di + ds].astype(F32)
    Cv = xBC[..., di + ds:].astype(F32)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))  # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(F32))                             # (nh,)
    dA = dt * A                                                      # <= 0
    xh = xc.reshape(B, S, nh, hp).astype(F32)
    u = xh * dt[..., None]                                           # B x dt

    # chunk
    r = lambda t, extra=(): t.reshape((B, NC, Q) + extra)
    uc = u.reshape(B, NC, Q, nh, hp)
    Bc = Bv.reshape(B, NC, Q, ds)
    Cc = Cv.reshape(B, NC, Q, ds)
    dAc = dA.reshape(B, NC, Q, nh)
    cs = jnp.cumsum(dAc, axis=2)                                     # inclusive

    # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) exp(cs_t - cs_s) u_s
    # mask the exponent BEFORE exp: upper-triangle (s > t) differences are
    # positive and would overflow -> inf * 0 = NaN.
    scores = jnp.einsum("bnqd,bnsd->bnqs", Cc, Bc)                   # shared heads
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]               # (B,NC,Q,Q,nh)
    tri = np.tril(np.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -jnp.inf))
    att = scores[..., None] * L                                      # (B,NC,Q,Q,nh)
    y_intra = jnp.einsum("bnqsh,bnshp->bnqhp", att, uc)

    # chunk states: S_n = sum_s B_s u_s exp(cs_end - cs_s)
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)                    # (B,NC,Q,nh)
    S_n = jnp.einsum("bnsd,bnshp,bnsh->bnhpd", Bc, uc, decay_to_end)
    gamma = jnp.exp(cs[:, :, -1])                                    # (B,NC,nh)

    # cross-chunk stitch (small scan)
    h0 = state["h"].astype(F32) if state is not None else \
        jnp.zeros((B, nh, hp, ds), F32)

    def step(h, inp):
        g_n, s_n = inp
        h_new = h * g_n[..., None, None] + s_n
        return h_new, h          # emit state at chunk START

    (h_last, h_prev) = jax.lax.scan(
        step, h0, (jnp.moveaxis(gamma, 1, 0), jnp.moveaxis(S_n, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                              # (B,NC,...)

    # inter-chunk: y[t] += C_t . (exp(cs_t) * h_prev)
    y_inter = jnp.einsum("bnqd,bnqh,bnhpd->bnqhp", Cc, jnp.exp(cs), h_prev)

    y = (y_intra + y_inter).reshape(B, S, nh, hp) + \
        xh * p["D"].astype(F32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y @ p["out_proj"].astype(dt_)
    new_state = {"h": h_last.astype(F32), "conv": new_tail}
    return y, new_state


def mamba2_decode(p, x, cfg: ModelConfig, state):
    """Single-token step.  x (B,1,D); state as above."""
    B, _, D = x.shape
    di, ds = cfg.ssm_inner, cfg.ssm_state
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = cfg.cdtype

    z, xBC, dt = _project(p, x, dt_)
    xBC, new_tail = _causal_conv(xBC, p["conv_w"].astype(dt_),
                                 p["conv_b"].astype(dt_), state["conv"])
    xc = xBC[..., :di]
    Bv = xBC[:, 0, di: di + ds].astype(F32)                    # (B, ds)
    Cv = xBC[:, 0, di + ds:].astype(F32)

    dt = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))
    g = jnp.exp(dt * A)                                        # (B, nh)
    xh = xc[:, 0].reshape(B, nh, hp).astype(F32)
    u = xh * dt[..., None]

    h = state["h"].astype(F32)                                 # (B,nh,hp,ds)
    h = h * g[..., None, None] + jnp.einsum("bd,bhp->bhpd", Bv, u)
    y = jnp.einsum("bhpd,bd->bhp", h, Cv) + xh * p["D"].astype(F32)[None, :, None]
    y = y.reshape(B, 1, di).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y @ p["out_proj"].astype(dt_)
    return y, {"h": h, "conv": new_tail}


def mamba2_state_specs(cfg: ModelConfig, batch: int):
    di, ds = cfg.ssm_inner, cfg.ssm_state
    nh, hp, ck = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    return {
        "h": jax.ShapeDtypeStruct((batch, nh, hp, ds), F32),
        "conv": jax.ShapeDtypeStruct((batch, ck - 1, di + 2 * ds), cfg.cdtype),
    }


# ---------------------------------------------------------------------------
# RWKV6 ("Finch"): time-mix with data-dependent per-channel decay + u bonus,
# and squared-relu channel-mix.
# ---------------------------------------------------------------------------

def init_rwkv6(ini: Init, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.pdtype
    lora = 64
    return {
        "tm": {
            "mu_r": ini.dense((d,), pd, 0.5), "mu_k": ini.dense((d,), pd, 0.5),
            "mu_v": ini.dense((d,), pd, 0.5), "mu_w": ini.dense((d,), pd, 0.5),
            "mu_g": ini.dense((d,), pd, 0.5),
            "w0": ini.dense((d,), pd, 0.5),
            "w_a": ini.dense((d, lora), pd), "w_b": ini.dense((lora, d), pd),
            "u": ini.dense((d,), pd, 0.5),
            "wr": ini.dense((d, d), pd), "wk": ini.dense((d, d), pd),
            "wv": ini.dense((d, d), pd), "wg": ini.dense((d, d), pd),
            "wo": ini.dense((d, d), pd),
            "ln_x": ini.ones((d,), pd),
        },
        "cm": {
            "mu_k": ini.dense((d,), pd, 0.5), "mu_r": ini.dense((d,), pd, 0.5),
            "wk": ini.dense((d, f), pd), "wv": ini.dense((f, d), pd),
            "wr": ini.dense((d, d), pd),
        },
    }


def _token_shift(x, last):
    """prev-token features; last (B,D) carries across calls (or zeros)."""
    if last is None:
        last = jnp.zeros((x.shape[0], x.shape[-1]), x.dtype)
    shifted = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return shifted, x[:, -1]


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _log_decay(p, xw, dt_):
    """per-channel log decay in (-RWKV_MAX_DECAY, -1e-6].

    The LoRA matmuls run in the compute dtype (bf16): their gradients are
    activation-sized (B,S,D) all-reduces under TP, and f32 doubles that
    traffic (SSPerf cell 2, iteration 2); only exp/clip stay f32."""
    lo = xw.astype(dt_) @ p["w_a"].astype(dt_)
    lo = jnp.tanh(lo) @ p["w_b"].astype(dt_)
    rate = jnp.exp(p["w0"].astype(F32) + lo.astype(F32))  # -log w, > 0
    return -jnp.clip(rate, 1e-6, RWKV_MAX_DECAY)


def rwkv6_time_mix(p, x, cfg: ModelConfig, state=None):
    """Chunked linear attention.  x (B,S,D).

    state: None or {"S": (B,nh,hd,hd) f32, "last": (B,D)}.
    """
    B, S, D = x.shape
    nh, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt_ = cfg.cdtype
    Q = min(cfg.rwkv_chunk, S)
    assert S % Q == 0
    NC = S // Q

    last = state["last"] if state is not None else None
    xs, new_last = _token_shift(x, last)
    xr = _mix(x, xs, p["mu_r"]); xk = _mix(x, xs, p["mu_k"])
    xv = _mix(x, xs, p["mu_v"]); xw = _mix(x, xs, p["mu_w"])
    xg = _mix(x, xs, p["mu_g"])
    r = (xr @ p["wr"].astype(dt_)).astype(F32).reshape(B, S, nh, hd)
    k = (xk @ p["wk"].astype(dt_)).astype(F32).reshape(B, S, nh, hd)
    v = (xv @ p["wv"].astype(dt_)).astype(F32).reshape(B, S, nh, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(dt_))
    logw = _log_decay(p, xw, dt_).reshape(B, S, nh, hd)
    u = p["u"].astype(F32).reshape(nh, hd)

    rc = r.reshape(B, NC, Q, nh, hd)
    kc = k.reshape(B, NC, Q, nh, hd)
    vc = v.reshape(B, NC, Q, nh, hd)
    lw = logw.reshape(B, NC, Q, nh, hd)
    cs = jnp.cumsum(lw, axis=2)                          # inclusive, <= 0
    cs_prev = cs - lw                                    # exclusive

    # intra-chunk (strictly earlier tokens): factored stable form
    r_s = rc * jnp.exp(cs_prev)
    k_s = kc * jnp.exp(-cs)                              # bounded by clamp
    att = jnp.einsum("bnqhd,bnshd->bnhqs", r_s, k_s)
    tri = np.tril(np.ones((Q, Q), np.float32), k=-1)     # strict lower
    att = att * tri[None, None, None]
    y = jnp.einsum("bnhqs,bnshd->bnqhd", att, vc)
    # current-token bonus: (sum_d r_d u_d k_d) * v
    bonus = jnp.einsum("bnqhd,hd,bnqhd->bnqh", rc, u, kc)
    y = y + bonus[..., None] * vc

    # chunk states
    decay_to_end = jnp.exp(cs[:, :, -1:, :, :] - cs)
    S_n = jnp.einsum("bnshd,bnshv->bnhdv", kc * decay_to_end, vc)
    gamma = jnp.exp(cs[:, :, -1])                        # (B,NC,nh,hd)

    h0 = state["S"].astype(F32) if state is not None else \
        jnp.zeros((B, nh, hd, hd), F32)

    def step(h, inp):
        g_n, s_n = inp
        return h * g_n[..., None] + s_n, h

    h_last, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(gamma, 1, 0), jnp.moveaxis(S_n, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)

    y = y + jnp.einsum("bnqhd,bnhdv->bnqhv", r_s, h_prev)

    # per-head group norm, gate, out proj
    y = y.reshape(B, S, nh, hd)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (y.reshape(B, S, D) * p["ln_x"].astype(F32)).astype(dt_)
    y = (y * g) @ p["wo"].astype(dt_)
    return y, {"S": h_last, "last": new_last}


def rwkv6_time_mix_decode(p, x, cfg: ModelConfig, state):
    B, _, D = x.shape
    nh, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt_ = cfg.cdtype
    xs = state["last"][:, None]
    xr = _mix(x, xs, p["mu_r"]); xk = _mix(x, xs, p["mu_k"])
    xv = _mix(x, xs, p["mu_v"]); xw = _mix(x, xs, p["mu_w"])
    xg = _mix(x, xs, p["mu_g"])
    r = (xr @ p["wr"].astype(dt_)).astype(F32).reshape(B, nh, hd)
    k = (xk @ p["wk"].astype(dt_)).astype(F32).reshape(B, nh, hd)
    v = (xv @ p["wv"].astype(dt_)).astype(F32).reshape(B, nh, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(dt_))
    w = jnp.exp(_log_decay(p, xw, dt_)).reshape(B, nh, hd)
    u = p["u"].astype(F32).reshape(nh, hd)

    S = state["S"].astype(F32)                            # (B,nh,hd,hd)
    wkv = S + jnp.einsum("bhd,bhv->bhdv", u[None].repeat(B, 0) * k, v)
    y = jnp.einsum("bhd,bhdv->bhv", r, wkv)
    S = S * w[..., None] + jnp.einsum("bhd,bhv->bhdv", k, v)

    y = y.reshape(B, 1, nh, hd)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (y.reshape(B, 1, D) * p["ln_x"].astype(F32)).astype(dt_)
    y = (y * g) @ p["wo"].astype(dt_)
    return y, {"S": S, "last": x[:, -1]}


def rwkv6_channel_mix(p, x, cfg: ModelConfig, last=None):
    dt_ = cfg.cdtype
    xs, new_last = _token_shift(x, last)
    xk = _mix(x, xs, p["mu_k"])
    xr = _mix(x, xs, p["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt_)))
    kv = k @ p["wv"].astype(dt_)
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt_)) * kv, new_last


def rwkv6_state_specs(cfg: ModelConfig, batch: int):
    nh, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "S": jax.ShapeDtypeStruct((batch, nh, hd, hd), F32),
        "last_tm": jax.ShapeDtypeStruct((batch, cfg.d_model), cfg.cdtype),
        "last_cm": jax.ShapeDtypeStruct((batch, cfg.d_model), cfg.cdtype),
    }
