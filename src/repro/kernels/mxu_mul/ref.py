"""Oracles for the MXU Toeplitz kernel: the jnp MXU path in core/mul.py
(itself oracle-tested against Python ints in tests/test_mul.py); kernel
tests additionally check against Python-int ground truth directly."""
from repro.core.mul import dot_mul_mxu, mul_limbs32


def mxu_mul_digits_ref(a_digits, b_digits):
    return dot_mul_mxu(a_digits, b_digits)


def mxu_mul_limbs32_ref(a_limbs, b_limbs):
    return mul_limbs32(a_limbs, b_limbs, method="mxu")
