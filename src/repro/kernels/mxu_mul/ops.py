"""Jit'd wrappers for the MXU Toeplitz multiplication kernel.

Digit entry point takes radix-2**7 digits (any int dtype, cast to int8);
the 32-bit limb entry point pays the radix conversion at entry/exit.
The tile heuristic is kernel-specific: the per-row Toeplitz band costs
~2*m*m int8 bytes per batch element (quadratic in m, unlike the linear
working sets of the VPU kernels), so the tile is sized against that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.mxu_mul import kernel as K
from repro.resilience import inject as _inject

U32 = jnp.uint32
I8 = jnp.int8


def _heuristic_tile(m: int, batch: int) -> int:
    bytes_per_elem = 2 * m * m + 32 * m          # T band + linear temps
    budget = 2 * tiling.TARGET_WORKING_SET_BYTES  # matmul band is the point
    tb = max(tiling.MIN_TILE, min(256, budget // max(1, bytes_per_elem)))
    return min(tb, max(tiling.MIN_TILE, batch))


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def _call(a, b, tb: int, interpret: bool):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    p = K.make_call(tb, m, grid, interpret)(a, b)
    return p[:batch]


def mxu_mul_digits(a_digits, b_digits, interpret=None):
    """(batch, m) radix-2**7 digits -> (batch, 2m) normalized digits."""
    a = jnp.asarray(a_digits, I8)
    b = jnp.asarray(b_digits, I8)
    interpret = _auto_interpret(interpret)
    batch, m = a.shape
    tb = autotune.pick_tile(
        "mxu_mul", (m, batch, K.MXU_DIGIT_BITS, interpret),
        _heuristic_tile(m, batch), batch,
        run=lambda t: _call(a, b, t, interpret), max_tile=256)
    return _call(a, b, tb, interpret)


def mxu_mul_limbs32(a_limbs, b_limbs, interpret=None):
    """(batch, m) uint32 saturated limbs -> (batch, 2m) limbs (full
    product), radix-converted 32 <-> 7 at entry/exit."""
    _inject.fire("kernels/mxu_mul")
    from repro.core import mul as coremul
    m = a_limbs.shape[-1]
    a_d = coremul.split_digits(jnp.asarray(a_limbs, U32), K.MXU_DIGIT_BITS)
    b_d = coremul.split_digits(jnp.asarray(b_limbs, U32), K.MXU_DIGIT_BITS)
    p_d = mxu_mul_digits(a_d, b_d, interpret)
    return coremul.join_digits(p_d, K.MXU_DIGIT_BITS, 2 * m)
