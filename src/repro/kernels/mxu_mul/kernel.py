"""Pallas TPU kernel for the MXU Toeplitz multiplication path.

The column sums of a digit product ARE a convolution, and a convolution
is a banded-Toeplitz matmul: cols[c] = sum_{i+j=c} a_i * b_j =
(a as 1 x m) @ T with T[i, i+j] = b_j.  With radix-2**7 digits in int8
and int32 accumulation this is a native MXU contraction -- the 128x128
systolic grid computes every partial product as an independent MAC cell,
the genuinely TPU-native realization of the paper's VnC insight (the
beyond-paper path of core/mul.dot_mul_mxu, now fused into one launch).

In-kernel schedule per program (one (TB, m) int8 block of each operand):
  P1/P2  T = skew(broadcast b)       -- static reshape, no data movement
  P3/P4  cols = a @ T                -- int8 x int8 -> int32 on the MXU
         (batched dot_general: every batch row has its own Toeplitz band)
  P5     static carry normalization at digit_bits=7; column sums are
         < m * 127**2, so the deferred-carry pass count computed from
         that bound (3 passes for m <= 2**13) plus the Kogge-Stone tail
         resolves exactly -- one resolve, in VMEM, like every other
         kernel in this family.

Output digits are normalized radix-2**7 values in uint32 (the storage
convention of core/mul.dot_mul_mxu after its normalize).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common.carry import normalize_static
from repro.kernels.common.vnc import skew as _skew

U32 = jnp.uint32
I32 = jnp.int32
MXU_DIGIT_BITS = 7

# Dominant VMEM term is the per-row Toeplitz band: ~2*m*m int8 bytes per
# batch element (see ops._heuristic_tile; the common per-(TB*m) budget
# formula does not capture the quadratic term).


def make_mxu_kernel(m: int):
    def mxu_mul_kernel(a_ref, b_ref, out_ref):
        a = a_ref[...]                            # (TB, m) int8 digits < 2**7
        b = b_ref[...]
        tb = a.shape[0]
        bt = jnp.broadcast_to(b[:, None, :], (tb, m, m))
        T = _skew(bt)                             # (TB, m, 2m-1) int8
        cols = jax.lax.dot_general(
            a, T, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=I32)           # (TB, 2m-1) on the MXU
        cols = jnp.concatenate(
            [cols, jnp.zeros((tb, 1), I32)], axis=1).astype(U32)
        out_ref[...] = normalize_static(
            cols, MXU_DIGIT_BITS, bound=m * 127 * 127 + 1)

    return mxu_mul_kernel


@functools.lru_cache(maxsize=32)
def make_call(batch_tile: int, m: int, grid: int, interpret: bool):
    return pl.pallas_call(
        make_mxu_kernel(m),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((batch_tile, 2 * m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, 2 * m), U32),
        interpret=interpret,
    )
