"""Pallas TPU kernel for DoT multi-limb addition/subtraction.

Grid: 1-D over batch tiles; each program owns a (TB, m) block of both
operands in VMEM.  The limb axis (m uint32 limbs, little-endian) maps to
VPU lanes; the batch tile maps to sublanes -- the TPU twin of issuing one
AVX-512 instruction across 8 lanes, amortized over thousands of
independent additions.

In-kernel schedule (branch-free; see core/add.py for the lax.cond "rare
slow path" formulation -- inside a kernel the log-depth unconditional
Phase 4 is cheaper than divergence):
  P1  r = a + b                       (one VPU add)
  P2  g = r < a ; p = r == MAX        (carry generate / propagate masks)
  P4' unrolled Kogge-Stone over the limb axis (log2(m) shift/or rounds)
  P3  s = r + shift_up(G)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

U32 = jnp.uint32
MAX32 = np.uint32(0xFFFFFFFF)


def ks_scan_unrolled(g, p):
    """Inclusive (generate, propagate) prefix scan along the last axis,
    unrolled into log2(m) shift rounds (identity element: g=0, p=1)."""
    m = g.shape[-1]
    d = 1
    while d < m:
        g_sh = jnp.concatenate(
            [jnp.zeros_like(g[..., :d]), g[..., :-d]], axis=-1)
        p_sh = jnp.concatenate(
            [jnp.ones_like(p[..., :d]), p[..., :-d]], axis=-1)
        g = g | (p & g_sh)
        p = p & p_sh
        d *= 2
    return g, p


def shift_up(c):
    return jnp.concatenate(
        [jnp.zeros_like(c[..., :1]), c[..., :-1]], axis=-1)


def add_kernel(a_ref, b_ref, s_ref, c_ref):
    a = a_ref[...]
    b = b_ref[...]
    r = a + b                                   # P1
    g = (r < a).astype(U32)                     # P2
    p = (r == MAX32).astype(U32)
    G, _ = ks_scan_unrolled(g, p)               # P4' (branch-free)
    s_ref[...] = r + shift_up(G)                # P3
    c_ref[...] = G[..., -1:]


def sub_kernel(a_ref, b_ref, s_ref, c_ref):
    a = a_ref[...]
    b = b_ref[...]
    r = a - b
    g = (a < b).astype(U32)                     # borrow generate
    p = (r == np.uint32(0)).astype(U32)        # borrow propagate
    G, _ = ks_scan_unrolled(g, p)
    s_ref[...] = r - shift_up(G)
    c_ref[...] = G[..., -1:]


def make_call(kernel, batch_tile: int, m: int, grid: int,
              interpret: bool):
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                   pl.BlockSpec((batch_tile, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((grid * batch_tile, m), U32),
            jax.ShapeDtypeStruct((grid * batch_tile, 1), U32),
        ],
        interpret=interpret,
    )
