"""Pallas TPU kernel for DoT multi-limb addition/subtraction.

Grid: 1-D over batch tiles; each program owns a (TB, m) block of both
operands in VMEM.  The limb axis (m uint32 limbs, little-endian) maps to
VPU lanes; the batch tile maps to sublanes -- the TPU twin of issuing one
AVX-512 instruction across 8 lanes, amortized over thousands of
independent additions.

In-kernel schedule (branch-free; see core/add.py for the lax.cond "rare
slow path" formulation -- inside a kernel the log-depth unconditional
Phase 4 is cheaper than divergence):
  P1  r = a + b                       (one VPU add)
  P2  g = r < a ; p = r == MAX        (carry generate / propagate masks)
  P4' unrolled Kogge-Stone over the limb axis (log2(m) shift/or rounds)
  P3  s = r + shift_up(G)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common.carry import ks_scan_unrolled, shift_up

U32 = jnp.uint32
MAX32 = np.uint32(0xFFFFFFFF)

# Simultaneously-live (TB, m) u32 arrays in the kernel body: a, b, r,
# g/p, G, s (see common/tiling.py for how this sizes the batch tile).
LIVE_U32_ARRAYS = 6
MAX_TILE = 512


def add_kernel(a_ref, b_ref, s_ref, c_ref):
    a = a_ref[...]
    b = b_ref[...]
    r = a + b                                   # P1
    g = (r < a).astype(U32)                     # P2
    p = (r == MAX32).astype(U32)
    G, _ = ks_scan_unrolled(g, p)               # P4' (branch-free)
    s_ref[...] = r + shift_up(G)                # P3
    c_ref[...] = G[..., -1:]


def sub_kernel(a_ref, b_ref, s_ref, c_ref):
    a = a_ref[...]
    b = b_ref[...]
    r = a - b
    g = (a < b).astype(U32)                     # borrow generate
    p = (r == np.uint32(0)).astype(U32)        # borrow propagate
    G, _ = ks_scan_unrolled(g, p)
    s_ref[...] = r - shift_up(G)
    c_ref[...] = G[..., -1:]


def make_call(kernel, batch_tile: int, m: int, grid: int,
              interpret: bool):
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                   pl.BlockSpec((batch_tile, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((grid * batch_tile, m), U32),
            jax.ShapeDtypeStruct((grid * batch_tile, 1), U32),
        ],
        interpret=interpret,
    )
