"""Pure-jnp oracle for the DoT addition kernel.

The kernel computes batched multi-limb addition with a full carry resolve:
semantically identical to core.add.dot_add_unconditional (phases 1-3 plus
the branch-free Kogge-Stone Phase 4), which is itself oracle-tested against
Python integers in tests/test_add.py.
"""
from repro.core.add import dot_add_unconditional, dot_sub_unconditional


def dot_add_ref(a, b):
    """(batch, m) uint32 x2 -> ((batch, m) sum, (batch,) carry_out)."""
    return dot_add_unconditional(a, b)


def dot_sub_ref(a, b):
    return dot_sub_unconditional(a, b)
