"""Jit'd wrappers for the DoT add/sub Pallas kernels.

Interpret mode is selected automatically on CPU (the kernel body runs as
Python/jnp for correctness validation); on TPU the same BlockSpecs tile
VMEM.  Batch is padded to the tile size and trimmed after the call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dot_add import kernel as K

U32 = jnp.uint32


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _tile_for(m: int, batch: int) -> int:
    # keep the (a, b, s, + temps) working set well under VMEM (~16 MB):
    # ~6 live (TB, m) u32 arrays -> TB*m <= 64k words  (~1.5 MB).
    tb = max(8, min(512, (64 * 1024) // max(8, m)))
    return min(tb, max(8, batch))


@functools.partial(jax.jit, static_argnames=("interpret", "op"))
def _call(a, b, interpret: bool, op: str):
    batch, m = a.shape
    tb = _tile_for(m, batch)
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    kern = K.add_kernel if op == "add" else K.sub_kernel
    s, c = K.make_call(kern, tb, m, grid, interpret)(a, b)
    return s[:batch], c[:batch, 0]


def dot_add(a, b, interpret=None):
    """(batch, m) uint32 x2 -> ((batch, m) sum, (batch,) carry_out)."""
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    return _call(a, b, _auto_interpret(interpret), "add")


def dot_sub(a, b, interpret=None):
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    return _call(a, b, _auto_interpret(interpret), "sub")
