"""Jit'd wrappers for the DoT add/sub Pallas kernels.

Interpret mode is selected automatically on CPU (the kernel body runs as
Python/jnp for correctness validation); on TPU the same BlockSpecs tile
VMEM.  Batch is padded to the tile size and trimmed after the call.

Tile selection lives OUTSIDE the jit boundary so the shared autotuner
(kernels/common/autotune, opt-in via REPRO_AUTOTUNE=1) can sweep real
timed calls; the default is the deterministic VMEM-budget heuristic in
kernels/common/tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.dot_add import kernel as K

U32 = jnp.uint32


def _heuristic_tile(m: int, batch: int) -> int:
    return tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit, static_argnames=("tb", "interpret", "op"))
def _call(a, b, tb: int, interpret: bool, op: str):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    kern = K.add_kernel if op == "add" else K.sub_kernel
    s, c = K.make_call(kern, tb, m, grid, interpret)(a, b)
    return s[:batch], c[:batch, 0]


def _run(a, b, op: str, interpret):
    interpret = _auto_interpret(interpret)
    batch, m = a.shape
    tb = autotune.pick_tile(
        f"dot_{op}", (m, batch, 32, interpret),
        _heuristic_tile(m, batch), batch,
        run=lambda t: _call(a, b, t, interpret, op), max_tile=K.MAX_TILE)
    return _call(a, b, tb, interpret, op)


def dot_add(a, b, interpret=None):
    """(batch, m) uint32 x2 -> ((batch, m) sum, (batch,) carry_out)."""
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    return _run(a, b, "add", interpret)


def dot_sub(a, b, interpret=None):
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    return _run(a, b, "sub", interpret)
