"""Python-int oracle for the fused Montgomery kernel.

Python ints ARE the reference bignum implementation (see core/limbs.py):
the oracle computes a*b*R^{-1} mod n and x^e mod n exactly, host-side,
digit-for-digit comparable with the kernel output.  Unlike dot_add/ref
(which reuses the jnp core path), the Montgomery oracle is deliberately
independent of ALL jnp code so a kernel bug and a core/modular.py bug
cannot cancel.
"""
from __future__ import annotations

import numpy as np

from repro.core import limbs as L

DIGIT_BITS = 16


def mont_mul_int_ref(a: int, b: int, n: int, m: int) -> int:
    """a * b * R^{-1} mod n with R = 2**(16*m), via pow()."""
    R = 1 << (DIGIT_BITS * m)
    return (a * b * pow(R, -1, n)) % n


def mont_mul_ref(a_digits: np.ndarray, b_digits: np.ndarray,
                 n: int) -> np.ndarray:
    """(batch, m) digit arrays -> (batch, m) digits of a*b*R^{-1} mod n."""
    a_digits = np.asarray(a_digits)
    b_digits = np.asarray(b_digits)
    m = a_digits.shape[-1]
    outs = []
    for i in range(a_digits.shape[0]):
        x = L.limbs_to_int(a_digits[i], DIGIT_BITS)
        y = L.limbs_to_int(b_digits[i], DIGIT_BITS)
        outs.append(L.int_to_limbs(mont_mul_int_ref(x, y, n, m),
                                   m, DIGIT_BITS))
    return np.stack(outs)


def mod_exp_ref(base_digits: np.ndarray, e: int, n: int) -> np.ndarray:
    """(batch, m) digits -> (batch, m) digits of base**e mod n."""
    base_digits = np.asarray(base_digits)
    m = base_digits.shape[-1]
    outs = []
    for i in range(base_digits.shape[0]):
        x = L.limbs_to_int(base_digits[i], DIGIT_BITS)
        outs.append(L.int_to_limbs(pow(x, e, n), m, DIGIT_BITS))
    return np.stack(outs)


def mod_exp_ref_lanes(base_digits: np.ndarray, exps: list[int],
                      n: int) -> np.ndarray:
    """Per-lane exponent oracle for the batched-exponent ladder variant:
    lane i computes base[i] ** exps[i] mod n (host pow, exact)."""
    base_digits = np.asarray(base_digits)
    m = base_digits.shape[-1]
    assert base_digits.shape[0] == len(exps)
    outs = []
    for i, e in enumerate(exps):
        x = L.limbs_to_int(base_digits[i], DIGIT_BITS)
        outs.append(L.int_to_limbs(pow(x, int(e), n), m, DIGIT_BITS))
    return np.stack(outs)
