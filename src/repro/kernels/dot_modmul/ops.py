"""Jit'd wrappers for the fused Montgomery-multiply / modexp kernels.

Mirrors dot_add/ops: interpret mode auto-selected on CPU, batch padded to
the tile size and trimmed after the call.  The kernels are specialized
per modulus (n0p baked in); the modulus digit vector rides along as a
(1, m) operand broadcast to every program.

``dot_mod_exp`` is the fused full-ladder windowed modexp: the exponent
bits are packed into k-ary window values host/jnp-side and the ENTIRE
constant-time ladder (power table build, all squarings, branch-free
table selects, Montgomery entry/exit) runs inside ONE kernel launch
whose (TB, m) residue and (2**w, TB, m) power table stay VMEM-resident
throughout -- versus the PR-3 driver's two launches per exponent bit.

Accepts any Montgomery context exposing ``m / n0p / n_digits / r2_digits
/ one_digits`` (core.modular.MontCtx); kept duck-typed so the kernel
layer has no dependency on the dispatch layer built on top of it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.dot_bignum import pick_modexp_window
from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.common.windows import exponent_windows
from repro.kernels.dot_modmul import kernel as K
from repro.resilience import inject as _inject

U32 = jnp.uint32

# Lazy-digit overflow bound (see core/modular.py): digits < 5*m*2**16
# must stay below 2**32.
MAX_DIGITS = 1 << 13


def _tile_for(m: int, batch: int) -> int:
    return tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit, static_argnames=("tb", "n0p", "interpret"))
def _mont_mul_call(a, b, n_row, tb: int, n0p: int, interpret: bool):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    out = K.make_call(tb, m, grid, n0p, interpret)(a, b, n_row)
    return out[:batch]


@functools.partial(jax.jit,
                   static_argnames=("tb", "n0p", "window", "interpret"))
def _ladder_call(base, wins, n_row, r2_row, one_row, tb: int, n0p: int,
                 window: int, interpret: bool):
    batch, m = base.shape
    pad = (-batch) % tb
    if pad:
        base = jnp.pad(base, ((0, pad), (0, 0)))
        # padded lanes exponentiate to 0**0 = 1 and are trimmed below
        wins = jnp.pad(wins, ((0, pad), (0, 0)))
    grid = base.shape[0] // tb
    out = K.make_ladder_call(tb, m, grid, n0p, window, wins.shape[-1],
                             interpret)(base, wins, n_row, r2_row, one_row)
    return out[:batch]


def dot_mont_mul(a, b, ctx, interpret=None):
    """(batch, m) digit arrays x2 -> (batch, m) of a*b*R^{-1} mod n."""
    _inject.fire("kernels/dot_modmul/mont_mul")
    assert ctx.m <= MAX_DIGITS, "lazy digits overflow uint32 beyond 2**13"
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    n_row = jnp.asarray(ctx.n_digits, U32)[None, :]
    interpret = _auto_interpret(interpret)
    n0p = int(ctx.n0p)
    batch, m = a.shape
    tb = autotune.pick_tile(
        "dot_modmul", (m, batch, 16, n0p, interpret),
        _tile_for(m, batch), batch,
        run=lambda t: _mont_mul_call(a, b, n_row, t, n0p, interpret),
        max_tile=K.MAX_TILE)
    return _mont_mul_call(a, b, n_row, tb, n0p, interpret)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def _barrett_mul_call(a, b, n_row, mu_row, tb: int, interpret: bool):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    out = K.make_barrett_call(tb, m, grid, interpret)(a, b, n_row, mu_row)
    return out[:batch]


@functools.partial(jax.jit, static_argnames=("tb", "window", "interpret"))
def _barrett_ladder_call(base, wins, n_row, mu_row, tb: int, window: int,
                         interpret: bool):
    batch, m = base.shape
    pad = (-batch) % tb
    if pad:
        base = jnp.pad(base, ((0, pad), (0, 0)))
        # padded lanes exponentiate to 0**0 = 1 and are trimmed below
        wins = jnp.pad(wins, ((0, pad), (0, 0)))
    grid = base.shape[0] // tb
    out = K.make_barrett_ladder_call(tb, m, grid, window, wins.shape[-1],
                                     interpret)(base, wins, n_row, mu_row)
    return out[:batch]


def dot_barrett_mul(a, b, ctx, interpret=None):
    """(batch, m) digit arrays x2 -> (batch, m) of a*b mod n via the
    fused Barrett kernel (no Montgomery form; any modulus parity).

    ``ctx`` is duck-typed on ``m / n_digits / mu_digits``
    (core.modular.BarrettCtx); n and mu ride in as runtime rows, so one
    compiled kernel serves every same-width modulus."""
    _inject.fire("kernels/dot_modmul/barrett_mul")
    assert ctx.m <= MAX_DIGITS, "lazy digits overflow uint32 beyond 2**13"
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    n_row = jnp.asarray(ctx.n_digits, U32)[None, :]
    mu_row = jnp.asarray(ctx.mu_digits, U32)[None, :]
    interpret = _auto_interpret(interpret)
    batch, m = a.shape
    tb = autotune.pick_tile(
        "dot_barrett_mul", (m, batch, 16, interpret),
        tiling.batch_tile(
            m, batch, budget=tiling.budget_words(K.BARRETT_LIVE_U32_ARRAYS),
            max_tile=K.MAX_TILE),
        batch,
        run=lambda t: _barrett_mul_call(a, b, n_row, mu_row, t, interpret),
        max_tile=K.MAX_TILE)
    return _barrett_mul_call(a, b, n_row, mu_row, tb, interpret)


def dot_barrett_mod_exp(base, exp_bits, ctx, window=None, interpret=None):
    """Fused full-ladder windowed modexp via Barrett reduction: the even-
    modulus twin of dot_mod_exp (same one-launch constant-time schedule,
    no Montgomery entry/exit).  ``ctx`` duck-typed as dot_barrett_mul."""
    _inject.fire("kernels/dot_modmul/barrett_mod_exp")
    assert ctx.m <= MAX_DIGITS, "lazy digits overflow uint32 beyond 2**13"
    base = jnp.asarray(base, U32)
    eb = jnp.asarray(exp_bits, U32)
    if eb.ndim == 1:
        eb = jnp.broadcast_to(eb, (base.shape[0], eb.shape[-1]))
    w = int(window if window is not None
            else pick_modexp_window(eb.shape[-1]))
    wins = exponent_windows(eb, w)
    n_row = jnp.asarray(ctx.n_digits, U32)[None, :]
    mu_row = jnp.asarray(ctx.mu_digits, U32)[None, :]
    interpret = _auto_interpret(interpret)
    batch, m = base.shape
    # heuristic tile only, for the same reason as dot_mod_exp
    tb = tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.barrett_live_arrays(w)),
        max_tile=K.MAX_TILE)
    return _barrett_ladder_call(base, wins, n_row, mu_row, tb, w, interpret)


def dot_mod_exp(base, exp_bits, ctx, window=None, interpret=None):
    """(batch, m) digits ** exp -> (batch, m) digits of base**e mod n,
    the whole windowed ladder fused into ONE kernel launch.

    exp_bits: (nbits,) or (batch, nbits) bits MSB-first (uint32/int32);
    per-lane exponents share nbits but may differ per batch element.
    ``window`` overrides the config-picked window size w.  Constant-time
    in structure: exponent windows feed one-hot selects, never branches.
    """
    _inject.fire("kernels/dot_modmul/mod_exp")
    assert ctx.m <= MAX_DIGITS, "lazy digits overflow uint32 beyond 2**13"
    base = jnp.asarray(base, U32)
    eb = jnp.asarray(exp_bits, U32)
    if eb.ndim == 1:
        eb = jnp.broadcast_to(eb, (base.shape[0], eb.shape[-1]))
    w = int(window if window is not None
            else pick_modexp_window(eb.shape[-1]))
    wins = exponent_windows(eb, w)
    n_row = jnp.asarray(ctx.n_digits, U32)[None, :]
    r2_row = jnp.asarray(ctx.r2_digits, U32)[None, :]
    one_row = jnp.asarray(ctx.one_digits, U32)[None, :]
    interpret = _auto_interpret(interpret)
    n0p = int(ctx.n0p)
    batch, m = base.shape
    # Heuristic tile only: the 2**w-row power table inflates the live
    # working set (ladder_live_arrays), and a timed autotune sweep would
    # re-run the WHOLE ladder per candidate -- not worth it for a kernel
    # whose launch count is already 1 per modexp.
    tb = tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.ladder_live_arrays(w)),
        max_tile=K.MAX_TILE)
    return _ladder_call(base, wins, n_row, r2_row, one_row, tb, n0p, w,
                        interpret)
