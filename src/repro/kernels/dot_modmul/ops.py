"""Jit'd wrappers for the fused Montgomery-multiply Pallas kernel.

Mirrors dot_add/ops: interpret mode auto-selected on CPU, batch padded to
the tile size and trimmed after the call.  The kernel is specialized per
modulus (n0p baked in); the modulus digit vector rides along as a (1, m)
operand broadcast to every program.

``dot_mod_exp`` is the batched constant-time square-and-multiply driver:
both branches computed every bit, result selected by the exponent bit --
each ladder step is two fused kernel launches whose (TB, m) working set
stays in VMEM for the whole CIOS loop.

Accepts any Montgomery context exposing ``m / n0p / n_digits / r2_digits
/ one_digits`` (core.modular.MontCtx); kept duck-typed so the kernel
layer has no dependency on the dispatch layer built on top of it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.dot_modmul import kernel as K

U32 = jnp.uint32

# Lazy-digit overflow bound (see core/modular.py): digits < 5*m*2**16
# must stay below 2**32.
MAX_DIGITS = 1 << 13


def _tile_for(m: int, batch: int) -> int:
    return tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit, static_argnames=("tb", "n0p", "interpret"))
def _mont_mul_call(a, b, n_row, tb: int, n0p: int, interpret: bool):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    out = K.make_call(tb, m, grid, n0p, interpret)(a, b, n_row)
    return out[:batch]


@functools.partial(jax.jit, static_argnames=("tb", "n0p", "interpret"))
def _mod_exp_call(base, eb, n_row, r2_row, one_row, tb: int, n0p: int,
                  interpret: bool):
    batch, m = base.shape
    pad = (-batch) % tb
    if pad:
        base = jnp.pad(base, ((0, pad), (0, 0)))
        eb = jnp.pad(eb, ((0, pad), (0, 0)))
    bp = base.shape[0]
    grid = bp // tb
    call = K.make_call(tb, m, grid, n0p, interpret)

    def mm(x, y):
        return call(x, y, n_row)

    x = mm(base, jnp.broadcast_to(r2_row, (bp, m)))   # to Montgomery form
    res0 = jnp.broadcast_to(one_row, (bp, m)).astype(U32)
    eb_t = jnp.moveaxis(eb, -1, 0)                    # (nbits, bp)

    def step(res, bit):
        sq = mm(res, res)
        mul = mm(sq, x)
        return jnp.where((bit == 1)[:, None], mul, sq), None

    res, _ = jax.lax.scan(step, res0, eb_t)
    plain_one = jnp.zeros((1, m), U32).at[0, 0].set(1)
    out = mm(res, jnp.broadcast_to(plain_one, (bp, m)))  # leave Mont form
    return out[:batch]


def dot_mont_mul(a, b, ctx, interpret=None):
    """(batch, m) digit arrays x2 -> (batch, m) of a*b*R^{-1} mod n."""
    assert ctx.m <= MAX_DIGITS, "lazy digits overflow uint32 beyond 2**13"
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    n_row = jnp.asarray(ctx.n_digits, U32)[None, :]
    interpret = _auto_interpret(interpret)
    n0p = int(ctx.n0p)
    batch, m = a.shape
    tb = autotune.pick_tile(
        "dot_modmul", (m, batch, 16, n0p, interpret),
        _tile_for(m, batch), batch,
        run=lambda t: _mont_mul_call(a, b, n_row, t, n0p, interpret),
        max_tile=K.MAX_TILE)
    return _mont_mul_call(a, b, n_row, tb, n0p, interpret)


def dot_mod_exp(base, exp_bits, ctx, interpret=None):
    """(batch, m) digits ** exp -> (batch, m) digits of base**e mod n.

    exp_bits: (nbits,) or (batch, nbits) bits MSB-first (uint32/int32).
    Constant-time ladder: square always, multiply always, select by bit.
    """
    assert ctx.m <= MAX_DIGITS, "lazy digits overflow uint32 beyond 2**13"
    base = jnp.asarray(base, U32)
    eb = jnp.asarray(exp_bits, U32)
    if eb.ndim == 1:
        eb = jnp.broadcast_to(eb, (base.shape[0], eb.shape[-1]))
    n_row = jnp.asarray(ctx.n_digits, U32)[None, :]
    r2_row = jnp.asarray(ctx.r2_digits, U32)[None, :]
    one_row = jnp.asarray(ctx.one_digits, U32)[None, :]
    interpret = _auto_interpret(interpret)
    n0p = int(ctx.n0p)
    batch, m = base.shape
    # tile chosen outside jit (same pallas_call as the mont-mul entry, so
    # the sweep shares its cache key and its VMEM-derived tile cap)
    tb = autotune.pick_tile(
        "dot_modmul", (m, batch, 16, n0p, interpret),
        _tile_for(m, batch), batch,
        run=lambda t: _mont_mul_call(
            base, jnp.broadcast_to(r2_row, base.shape), n_row, t, n0p,
            interpret),
        max_tile=K.MAX_TILE)
    return _mod_exp_call(base, eb, n_row, r2_row, one_row, tb, n0p,
                         interpret)
