"""Fused Pallas TPU kernel for batched CIOS Montgomery multiplication.

One program owns a (TB, m) block of both operands in VMEM and runs the
FULL Montgomery product there: m CIOS iterations with lazy radix-2**16
digits (deferred carries, per the overflow analysis in core/modular.py),
then ONE carry-resolve pass and the branch-free conditional subtract.
The jnp formulation in core/modular.py round-trips the (m+1)-digit
accumulator through HBM on every scan step; here the accumulator never
leaves vregs -- the TPU twin of the paper's "keep the redundant
representation in registers across the whole CIOS loop" (sec 4.4, DoTSSL)
and of Meng's vectorized-Montgomery generation.

In-kernel schedule per iteration i (all VPU ops over the batch tile):
  P1  acc += a_i * b          (lo into column j, hi into j+1 -- lazy)
  P2  u = (acc_0 mod B) * n0p mod B
  P3  acc += u * n            (digit 0 becomes 0 mod B)
  P4  shift acc down one digit, folding acc_0's high part into the new
      digit 0 (static slice -- no data movement beyond the vreg shuffle)
After m iterations: digits < 5*m*2**16 (safe in uint32 for m <= 2**13),
one normalize_static pass brings t < 2n to normalized digits, and the
radix-complement subtract selects t or t - n without branching.

n0p and m are BAKED into the kernel (host-side Montgomery constants --
one specialization per modulus, exactly the serving pattern: a key is
loaded once, then millions of modmuls reuse the compiled kernel).

``make_ladder_call`` composes the same multiply into the fused
full-ladder windowed modexp kernel: ONE launch runs the entire k-ary
exponentiation (Montgomery entry, 2**w-entry power table build, all
squarings and branch-free one-hot table selects, Montgomery exit) with
everything VMEM-resident -- versus two launches per exponent bit when
the ladder is composed outside the kernel.  Its loops are
lax.fori_loops (see cios_iterations_loop) so compile time stays flat
in nbits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common.carry import normalize_static

U32 = jnp.uint32
DMASK = np.uint32(0xFFFF)
DBITS = np.uint32(16)

# ~8 live (TB, m+1) u32 arrays in the CIOS loop (a, b, n, acc, two
# product temps, normalize temps) + headroom; sizes the batch tile via
# common/tiling.
LIVE_U32_ARRAYS = 12
MAX_TILE = 256


def cios_iterations(a, b, n, n0p):
    """The lazy CIOS loop on (TB, m) blocks; returns the (TB, m+1) lazy
    accumulator with t = a*b*R^{-1} represented in deferred-carry digits.

    Unrolled over the m digits of a (the dependency chain inherent to
    Montgomery); every line is a full-width VPU op over the batch tile.
    """
    tb, m = a.shape
    n0p = np.uint32(n0p)
    acc = jnp.zeros((tb, m + 1), U32)
    for i in range(m):
        prod = a[:, i:i + 1] * b                  # exact uint32 products
        acc = acc.at[:, :m].add(prod & DMASK)
        acc = acc.at[:, 1:m + 1].add(prod >> DBITS)
        u = ((acc[:, 0:1] & DMASK) * n0p) & DMASK
        prod2 = u * n                             # (TB, m), exact uint32
        acc = acc.at[:, :m].add(prod2 & DMASK)
        acc = acc.at[:, 1:m + 1].add(prod2 >> DBITS)
        # digit 0 is now 0 mod B: shift down, carrying its high part
        c0 = acc[:, 0:1] >> DBITS
        acc = jnp.concatenate(
            [acc[:, 1:], jnp.zeros((tb, 1), U32)], axis=1)
        acc = acc.at[:, 0:1].add(c0)
    return acc


def cond_subtract(t, n):
    """Branch-free conditional subtract: t if t < n else t - n.

    t: (TB, m+1) normalized digits with t < 2n; n: (1, m) or (TB, m).
    Radix-complement add computes t - n + B**(m+1); the carry out of the
    top digit (1 iff t >= n) selects between the two candidates.
    """
    tb = t.shape[0]
    m = t.shape[1] - 1
    comp = jnp.concatenate(
        [DMASK - n, jnp.full((n.shape[0], 1), DMASK, U32)], axis=1)
    s = (t + comp).at[:, 0:1].add(1)              # lazy, < 2**17 + 1
    ext = jnp.concatenate([s, jnp.zeros((tb, 1), U32)], axis=1)
    sn = normalize_static(ext)                    # (TB, m+2)
    ge = sn[:, m + 1:m + 2]                       # carry out: 1 iff t >= n
    return jnp.where(ge == 1, sn[:, :m], t[:, :m])


def cios_iterations_loop(a, b, n, n0p):
    """cios_iterations with the digit loop as a lax.fori_loop instead of
    a trace-time unroll.

    Semantically identical; used by the fused ladder kernel, where the
    unrolled form would inline m iterations into EVERY one of the
    ~nbits*(1+1/w) multiplies of the window loop body and blow up
    compile time.  The single-multiply kernel keeps the unrolled form
    (static slices, nothing else in the launch to amortize against).
    """
    tb, m = a.shape
    n0p = np.uint32(n0p)

    def body(i, acc):
        ai = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)   # (TB, 1)
        prod = ai * b                             # exact uint32 products
        acc = acc.at[:, :m].add(prod & DMASK)
        acc = acc.at[:, 1:m + 1].add(prod >> DBITS)
        u = ((acc[:, 0:1] & DMASK) * n0p) & DMASK
        prod2 = u * n                             # (TB, m), exact uint32
        acc = acc.at[:, :m].add(prod2 & DMASK)
        acc = acc.at[:, 1:m + 1].add(prod2 >> DBITS)
        c0 = acc[:, 0:1] >> DBITS
        acc = jnp.concatenate(
            [acc[:, 1:], jnp.zeros((tb, 1), U32)], axis=1)
        acc = acc.at[:, 0:1].add(c0)
        return acc

    return jax.lax.fori_loop(0, m, body, jnp.zeros((tb, m + 1), U32))


def mont_mul_block(a, b, n, n0p):
    """Full normalized Montgomery product on (TB, m) blocks (loop CIOS +
    carry resolve + branch-free conditional subtract) -- the multiply
    the fused ladder kernel composes ~nbits*(1+1/w) times per launch."""
    acc = cios_iterations_loop(a, b, n, n0p)
    return cond_subtract(normalize_static(acc), n)


def make_mont_kernel(m: int, n0p: int):
    """Kernel body specialized to a modulus width m and constant n0p."""

    def mont_mul_kernel(a_ref, b_ref, n_ref, out_ref):
        a = a_ref[...]                            # (TB, m) digits < 2**16
        b = b_ref[...]
        n = n_ref[...]                            # (1, m) modulus digits
        acc = cios_iterations(a, b, n, n0p)
        t = normalize_static(acc)                 # single deferred resolve
        out_ref[...] = cond_subtract(t, n)

    return mont_mul_kernel


def ladder_live_arrays(window: int) -> int:
    """Live (TB, ~m) uint32 arrays in the fused ladder kernel: the
    2**w-row power table dominates, plus the same ~12 CIOS/normalize
    temps as the single-multiply kernel.  Sizes the batch tile."""
    return (1 << window) + LIVE_U32_ARRAYS


def make_ladder_kernel(m: int, n0p: int, window: int, nwin: int):
    """Fused full-ladder windowed modexp kernel body.

    One program owns a (TB, m) residue block and runs the ENTIRE k-ary
    exponentiation there -- to-Montgomery transform, 2**w-entry power
    table build, all nwin windows (w squarings + one branch-free one-hot
    table select + multiply each), and the from-Montgomery exit -- so a
    modexp is ONE kernel launch instead of two per exponent bit, and the
    residue/modulus/table never leave VMEM.  Per-lane exponents arrive
    as a (TB, nwin) array of window values (MSB-first, each < 2**w);
    they only ever feed the one-hot select masks, never control flow,
    so the ladder is constant-time in structure.  w, nwin, m, n0p are
    all baked (one specialization per modulus/exponent geometry)."""
    nt = 1 << window

    def ladder_kernel(base_ref, win_ref, n_ref, r2_ref, one_ref, out_ref):
        base = base_ref[...]                      # (TB, m) digits < 2**16
        wins = win_ref[...]                       # (TB, nwin) window values
        n = n_ref[...]                            # (1, m) modulus digits
        tb = base.shape[0]

        def mm(x, y):
            return mont_mul_block(x, y, n, n0p)

        x = mm(base, jnp.broadcast_to(r2_ref[...], base.shape))   # to Mont
        table = [jnp.broadcast_to(one_ref[...], base.shape), x]
        for _ in range(2, nt):
            table.append(mm(table[-1], x))
        tab = jnp.stack(table[:nt])               # (2**w, TB, m) in VMEM
        iota = jax.lax.broadcasted_iota(U32, (nt, tb), 0)

        def select(j):
            d = jax.lax.dynamic_slice_in_dim(wins, j, 1, axis=1)  # (TB, 1)
            onehot = (iota == d.reshape(1, tb)).astype(U32)       # (2**w, TB)
            return jnp.sum(tab * onehot[:, :, None], axis=0)      # (TB, m)

        def win_step(j, res):
            for _ in range(window):
                res = mm(res, res)
            return mm(res, select(j))

        res = jax.lax.fori_loop(1, nwin, win_step, select(0))
        plain_one = (jax.lax.broadcasted_iota(U32, (1, m), 1) == 0)
        out_ref[...] = mm(res, jnp.broadcast_to(plain_one.astype(U32),
                                                base.shape))      # exit Mont

    return ladder_kernel


@functools.lru_cache(maxsize=64)
def make_ladder_call(batch_tile: int, m: int, grid: int, n0p: int,
                     window: int, nwin: int, interpret: bool):
    """pallas_call for the fused full-ladder windowed modexp.

    Inputs: base (grid*TB, m), window values (grid*TB, nwin), and the
    (1, m) modulus / R^2 / R-mod-n rows broadcast to every program.
    Output: (grid*TB, m) digits of base**e mod n.
    """
    return pl.pallas_call(
        make_ladder_kernel(m, n0p, window, nwin),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, nwin), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, m), U32),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# fused Barrett multiply (the even-modulus twin of the CIOS block)
# ---------------------------------------------------------------------------

# The Barrett block's full products keep ~2m-wide column temps live on
# top of the CIOS-style working set, so its tile budget counts them.
BARRETT_LIVE_U32_ARRAYS = 20


def full_mul_columns(a, b):
    """Lazy full product on blocks: a (TB, ma) x b (TB|1, mb) ->
    (TB, ma+mb) deferred-carry columns, each digit < 2*ma*2**16.

    The schoolbook column accumulation of kernels/dot_mul, restated as a
    lax.fori_loop over a's digits so the fused Barrett ladder (three of
    these per modular multiply, ~nbits*(1+1/w) multiplies per launch)
    traces one body instead of inlining ma iterations everywhere."""
    tb, ma = a.shape
    mb = b.shape[1]
    zeros1 = jnp.zeros((tb, 1), U32)

    def body(i, acc):
        ai = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)   # (TB, 1)
        prod = ai * b                             # exact uint32 products
        contrib = (jnp.concatenate([prod & DMASK, zeros1], axis=1)
                   + jnp.concatenate([zeros1, prod >> DBITS], axis=1))
        cur = jax.lax.dynamic_slice(acc, (0, i), (tb, mb + 1))
        return jax.lax.dynamic_update_slice(acc, cur + contrib, (0, i))

    return jax.lax.fori_loop(0, ma, body, jnp.zeros((tb, ma + mb), U32))


def cond_sub_ge(r, n):
    """Width-preserving branch-free conditional subtract: r if r < n
    else r - n, for r (TB, mw) normalized and n (1, mw).  Same radix-
    complement trick as cond_subtract, keeping all mw digits (Barrett's
    r < 3n needs m+1 digits until the final correction lands)."""
    tb, mw = r.shape
    s = (r + (DMASK - n)).at[:, 0:1].add(1)       # lazy, <= 2**17 + 1
    ext = jnp.concatenate([s, jnp.zeros((tb, 1), U32)], axis=1)
    sn = normalize_static(ext, bound=1 << 17)     # (TB, mw+1)
    ge = sn[:, mw:mw + 1]                         # carry out: 1 iff r >= n
    return jnp.where(ge == 1, sn[:, :mw], r)


def barrett_mul_block(a, b, n, mu):
    """Full Barrett modular product on (TB, m) blocks: a*b mod n with
    NO Montgomery form -- the only in-kernel multiply that serves even
    moduli.  Mirrors core/modular._barrett_reduce digit for digit:

      x = a*b                                  (full product, 2m digits)
      t = floor(x / B**(m-1))                  (static slice)
      q_hat = floor(t * mu / B**(m+1))         (truncated mu-multiply)
      r = x - q_hat*n  mod B**(m+1)            (radix-complement, exact
                                                since 0 <= x - q_hat*n
                                                < 3n < B**(m+1))
      two branch-free conditional subtracts    (q_hat >= q - 2)

    n: (1, m) and mu: (1, m+2) ride in as runtime rows (NOT baked), so
    one compiled kernel serves every same-width modulus."""
    tb, m = a.shape
    x = normalize_static(full_mul_columns(a, b),
                         bound=(2 * m) << 16)     # (TB, 2m), a*b exact
    t = x[:, m - 1:]                              # (TB, m+1)
    q_full = normalize_static(full_mul_columns(t, mu),
                              bound=(2 * (m + 1)) << 16)
    q = q_full[:, m + 1:2 * m + 2]                # (TB, m+1) q_hat
    p = normalize_static(full_mul_columns(q, n),
                         bound=(2 * (m + 1)) << 16)  # q_hat*n <= x < B**2m
    # r = x - p on m+1 digits: exact mod B**(m+1) because 0 <= x-p < 3n
    s = (x[:, :m + 1] + (DMASK - p[:, :m + 1])).at[:, 0:1].add(1)
    r = normalize_static(s, bound=1 << 17)        # carry past top drops
    n_ext = jnp.concatenate([n, jnp.zeros((1, 1), U32)], axis=1)
    r = cond_sub_ge(r, n_ext)
    r = cond_sub_ge(r, n_ext)
    return r[:, :m]


def make_barrett_kernel(m: int):
    """Single fused Barrett multiply kernel body (modulus width baked;
    the modulus itself arrives as runtime rows)."""

    def barrett_mul_kernel(a_ref, b_ref, n_ref, mu_ref, out_ref):
        out_ref[...] = barrett_mul_block(
            a_ref[...], b_ref[...], n_ref[...], mu_ref[...])

    return barrett_mul_kernel


def barrett_live_arrays(window: int) -> int:
    """Live (TB, ~m) uint32 arrays in the fused Barrett ladder: the
    2**w-row power table plus the Barrett block's double-width temps."""
    return (1 << window) + BARRETT_LIVE_U32_ARRAYS


def make_barrett_ladder_kernel(m: int, window: int, nwin: int):
    """Fused full-ladder windowed modexp on plain residues via Barrett
    reduction: same one-launch schedule as make_ladder_kernel (power
    table build, w squarings + one-hot select per window) minus the
    Montgomery entry/exit -- Barrett's identity is the literal digit 1,
    so even moduli get the single-launch ladder too."""
    nt = 1 << window

    def ladder_kernel(base_ref, win_ref, n_ref, mu_ref, out_ref):
        base = base_ref[...]                      # (TB, m) residues < n
        wins = win_ref[...]                       # (TB, nwin) window values
        n = n_ref[...]                            # (1, m) modulus digits
        mu = mu_ref[...]                          # (1, m+2) mu digits
        tb = base.shape[0]

        def mm(x, y):
            return barrett_mul_block(x, y, n, mu)

        one = (jax.lax.broadcasted_iota(U32, (1, m), 1) == 0).astype(U32)
        table = [jnp.broadcast_to(one, base.shape), base]
        for _ in range(2, nt):
            table.append(mm(table[-1], base))
        tab = jnp.stack(table[:nt])               # (2**w, TB, m) in VMEM
        iota = jax.lax.broadcasted_iota(U32, (nt, tb), 0)

        def select(j):
            d = jax.lax.dynamic_slice_in_dim(wins, j, 1, axis=1)  # (TB, 1)
            onehot = (iota == d.reshape(1, tb)).astype(U32)       # (2**w, TB)
            return jnp.sum(tab * onehot[:, :, None], axis=0)      # (TB, m)

        def win_step(j, res):
            for _ in range(window):
                res = mm(res, res)
            return mm(res, select(j))

        out_ref[...] = jax.lax.fori_loop(1, nwin, win_step, select(0))

    return ladder_kernel


@functools.lru_cache(maxsize=64)
def make_barrett_call(batch_tile: int, m: int, grid: int, interpret: bool):
    """pallas_call for the fused Barrett multiply.  Inputs: a, b
    (grid*TB, m) digit arrays plus (1, m) modulus and (1, m+2) mu rows
    broadcast to every program (runtime operands: the cache key is
    geometry only, one compilation per width)."""
    return pl.pallas_call(
        make_barrett_kernel(m),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0)),
                  pl.BlockSpec((1, m + 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, m), U32),
        interpret=interpret,
    )


@functools.lru_cache(maxsize=64)
def make_barrett_ladder_call(batch_tile: int, m: int, grid: int,
                             window: int, nwin: int, interpret: bool):
    """pallas_call for the fused Barrett full-ladder windowed modexp.
    Inputs: base (grid*TB, m), window values (grid*TB, nwin), and the
    (1, m) / (1, m+2) modulus and mu rows."""
    return pl.pallas_call(
        make_barrett_ladder_kernel(m, window, nwin),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, nwin), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0)),
                  pl.BlockSpec((1, m + 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, m), U32),
        interpret=interpret,
    )


@functools.lru_cache(maxsize=64)
def make_call(batch_tile: int, m: int, grid: int, n0p: int,
              interpret: bool):
    """pallas_call for the fused Montgomery multiply.

    Inputs: a, b (grid*TB, m) digit arrays and the (1, m) modulus block
    (broadcast to every program).  Output: (grid*TB, m) digits < n.
    """
    return pl.pallas_call(
        make_mont_kernel(m, n0p),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, m), U32),
        interpret=interpret,
    )
