"""Pallas TPU kernel for the DoT base-case multiplication (Algorithm 2).

One program multiplies a (TB,) batch tile of m-digit operands (radix
2**16 in uint32 -- the TPU twin of IFMA's 52-in-64).  The five phases:

  P1 gather   : implicit -- row i of the product triangle is a[:, i] * b
                (vectorized over the batch tile; every row independent).
  P2 products : one uint32 VPU multiply per row + lo/hi mask/shift
                (exactly simd_mul_lo / simd_mul_hi).
  P3 align    : static slice-adds place lo at columns [i, i+m) and hi at
                [i+1, i+m+1) -- the skew without data movement.
  P4 reduce   : the slice-adds ARE the column reduction (deferred carries;
                column sums < 2m * 2**16 << 2**32, provably no overflow).
  P5 carry    : two deferred-carry passes bring digits to <= 2**16, then
                an unrolled Kogge-Stone tail resolves the 0/1 residue --
                branch-free, unlike the sequential scan of Algorithm 2
                line 38 (the paper's own Phase-4 trick, reused here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common.carry import normalize_static

U32 = jnp.uint32
DMASK = np.uint32(0xFFFF)
DBITS = np.uint32(16)

# The (TB, 2m) column accumulator plus operands, products, and the
# normalize temps -- counted in (TB, m)-array equivalents for the
# common/tiling VMEM budget.
LIVE_U32_ARRAYS = 24
MAX_TILE = 256


def mul_kernel(a_ref, b_ref, p_ref):
    a = a_ref[...]                           # (TB, m) digits < 2**16
    b = b_ref[...]
    tb, m = a.shape
    cols = jnp.zeros((tb, 2 * m), U32)
    for i in range(m):                       # m independent rows, unrolled
        prod = a[:, i:i + 1] * b             # P2: exact uint32 products
        lo = prod & DMASK
        hi = prod >> DBITS
        cols = cols.at[:, i:i + m].add(lo)           # P3/P4
        cols = cols.at[:, i + 1:i + m + 1].add(hi)
    p_ref[...] = normalize_static(cols)      # P5


def make_call(batch_tile: int, m: int, grid: int, interpret: bool):
    return pl.pallas_call(
        mul_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((batch_tile, 2 * m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, 2 * m), U32),
        interpret=interpret,
    )
