"""Jit'd wrapper for the DoT base-case multiplication kernel.

Accepts either 16-bit digit arrays (native) or 32-bit limb arrays (the
GMP/OpenSSL-facing saturated radix; converted at entry/exit like the
paper's 4x4 routine pays for 64<->52 packing).  Tile selection happens
outside jit via kernels/common (heuristic by default, measured sweep
under REPRO_AUTOTUNE=1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import mul as coremul
from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.dot_mul import kernel as K
from repro.resilience import inject as _inject

U32 = jnp.uint32


def _heuristic_tile(m: int, batch: int) -> int:
    return tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def _call(a, b, tb: int, interpret: bool):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    p = K.make_call(tb, m, grid, interpret)(a, b)
    return p[:batch]


def dot_mul_digits(a_digits, b_digits, interpret=None):
    """(batch, m) uint32 radix-2**16 digits -> (batch, 2m) digits."""
    a = jnp.asarray(a_digits, U32)
    b = jnp.asarray(b_digits, U32)
    interpret = _auto_interpret(interpret)
    batch, m = a.shape
    tb = autotune.pick_tile(
        "dot_mul", (m, batch, 16, interpret),
        _heuristic_tile(m, batch), batch,
        run=lambda t: _call(a, b, t, interpret), max_tile=K.MAX_TILE)
    return _call(a, b, tb, interpret)


def dot_mul_limbs32(a_limbs, b_limbs, interpret=None):
    """(batch, m) uint32 saturated limbs -> (batch, 2m) limbs (full product),
    with radix conversion at entry/exit (paper sec 3.3, 4x4 routine)."""
    _inject.fire("kernels/dot_mul")
    m = a_limbs.shape[-1]
    a_d = coremul.split_digits(jnp.asarray(a_limbs, U32), 16)
    b_d = coremul.split_digits(jnp.asarray(b_limbs, U32), 16)
    p_d = dot_mul_digits(a_d, b_d, interpret)
    return coremul.join_digits(p_d, 16, 2 * m)
