"""Jit'd wrapper for the DoT base-case multiplication kernel.

Accepts either 16-bit digit arrays (native) or 32-bit limb arrays (the
GMP/OpenSSL-facing saturated radix; converted at entry/exit like the
paper's 4x4 routine pays for 64<->52 packing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import mul as coremul
from repro.kernels.dot_mul import kernel as K

U32 = jnp.uint32


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call(a, b, interpret: bool):
    batch, m = a.shape
    tb = max(8, min(256, (16 * 1024) // max(8, m)))
    tb = min(tb, max(8, batch))
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    p = K.make_call(tb, m, grid, interpret)(a, b)
    return p[:batch]


def dot_mul_digits(a_digits, b_digits, interpret=None):
    """(batch, m) uint32 radix-2**16 digits -> (batch, 2m) digits."""
    a = jnp.asarray(a_digits, U32)
    b = jnp.asarray(b_digits, U32)
    return _call(a, b, _auto_interpret(interpret))


def dot_mul_limbs32(a_limbs, b_limbs, interpret=None):
    """(batch, m) uint32 saturated limbs -> (batch, 2m) limbs (full product),
    with radix conversion at entry/exit (paper sec 3.3, 4x4 routine)."""
    m = a_limbs.shape[-1]
    a_d = coremul.split_digits(jnp.asarray(a_limbs, U32), 16)
    b_d = coremul.split_digits(jnp.asarray(b_limbs, U32), 16)
    p_d = dot_mul_digits(a_d, b_d, interpret)
    return coremul.join_digits(p_d, 16, 2 * m)
