"""Pure-jnp oracle for the DoT multiplication kernel: core.mul.dot_mul
(itself oracle-tested against Python-int products in tests/test_mul.py)."""
from repro.core.mul import dot_mul, mul_limbs32


def dot_mul_digits_ref(a_digits, b_digits):
    return dot_mul(a_digits, b_digits)


def dot_mul_limbs32_ref(a_limbs, b_limbs):
    return mul_limbs32(a_limbs, b_limbs, method="dot")
