"""Shared in-kernel carry machinery for every DoT Pallas kernel.

These are the three primitives the paper's Phase-4/Phase-5 tricks reduce
to on TPU, previously copy-pasted across dot_add / dot_mul / dot_modmul
(PR 1 left dot_mul importing from dot_add and dot_modmul importing from
dot_mul -- a dependency chain between sibling kernels).  They live here
now; every kernel imports from ``repro.kernels.common.carry`` and no
kernel depends on another kernel package.

All helpers are branch-free with STATIC control flow (Python loops
unrolled at trace time), which is what makes them kernel-safe: inside a
``pallas_call`` body there is no ``lax.while_loop`` over a data-dependent
carry count, so convergence bounds must be proven at build time instead
of checked at run time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32


def ks_scan_unrolled(g, p):
    """Inclusive (generate, propagate) prefix scan along the last axis,
    unrolled into log2(m) shift rounds (identity element: g=0, p=1).

    The Kogge-Stone carry network of DoT-add Phase 4', reused by every
    kernel that must resolve a residual 0/1 carry without a sequential
    pass.
    """
    m = g.shape[-1]
    d = 1
    while d < m:
        g_sh = jnp.concatenate(
            [jnp.zeros_like(g[..., :d]), g[..., :-d]], axis=-1)
        p_sh = jnp.concatenate(
            [jnp.ones_like(p[..., :d]), p[..., :-d]], axis=-1)
        g = g | (p & g_sh)
        p = p & p_sh
        d *= 2
    return g, p


def shift_up(c):
    """One-digit shift toward the most significant end (carry landing)."""
    return jnp.concatenate(
        [jnp.zeros_like(c[..., :1]), c[..., :-1]], axis=-1)


def normalize_static(cols, digit_bits: int = 16, bound: int = 1 << 32):
    """Exact carry normalization with static control flow (kernel-safe).

    cols holds lazy (deferred-carry) digits in uint32: the represented
    value is sum(cols[i] * 2**(digit_bits*i)) with each digit < ``bound``.
    Deferred-carry vector passes ``c <- (c & mask) + shift_up(c >> bits)``
    run until the per-digit bound is provably <= 2*mask + 1 (so the
    remaining carry is 0/1); the pass count is computed from ``bound`` at
    trace time, not from the data.  An unrolled Kogge-Stone tail then
    resolves the 0/1 residue branch-free (the paper's own Phase-4 trick,
    applied to Phase 5).

    The value is preserved modulo 2**(digit_bits*len): callers must size
    the array so the true result fits (every kernel here does, see the
    per-kernel bound notes).
    """
    assert 1 <= digit_bits <= 16, "digit products must fit in uint32"
    mask = np.uint32((1 << digit_bits) - 1)
    bits = np.uint32(digit_bits)
    b = int(bound)
    assert b <= 1 << 32, "lazy digits must fit in uint32"
    while b > 2 * int(mask) + 1:
        cols = (cols & mask) + shift_up(cols >> bits)
        b = int(mask) + (b >> digit_bits)
    g = (cols >> bits).astype(U32)           # residual carry, in {0, 1}
    low = cols & mask
    p = (low == mask).astype(U32)
    G, _ = ks_scan_unrolled(g, p)
    return (low + shift_up(G)) & mask
