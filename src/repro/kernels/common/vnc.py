"""Shared VnC (vertical-and-crosswise) building blocks for multiply kernels.

Two realizations of the same Phase 1-4 math (all partial products,
aligned to columns, reduced with deferred carries):

* ``vnc_cols_rows``: an unrolled row loop of slice-adds -- the VPU-native
  schedule (each step is one full-width multiply plus two lane-aligned
  accumulations; no m-fold memory blowup).  Best on TPU.
* ``vnc_cols_skew``: materialize the full (..., m, m) product triangle
  and reduce it via the static skew-reshape -- one big vectorized
  contraction instead of m dependent updates.  Best where the serial
  row-loop chain dominates (CPU interpret mode); memory is O(m) larger.

Kernel wrappers pick per backend (see kara_mul/ops.py); both are exact
for digits < 2**16 held in uint32.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
DBITS = 16
DMASK = np.uint32((1 << DBITS) - 1)


def skew(mat):
    """out[..., i, i+j] = mat[..., i, j]: anti-diagonals become columns."""
    *lead, m, m2 = mat.shape
    assert m == m2, "square (..., m, m) expected"
    pad = jnp.pad(mat, [(0, 0)] * len(lead) + [(0, 0), (0, m)])
    flat = pad.reshape(*lead, m * 2 * m)
    flat = flat[..., : m * (2 * m - 1)]
    return flat.reshape(*lead, m, 2 * m - 1)


def vnc_cols_rows(a, b):
    """(..., nb) x2 uint32 digits -> (..., 2nb) lazy cols (row-loop form).

    Works for any leading batch shape; the loop is unrolled at trace
    time (nb static).  The lo and hi halves of each row are pre-combined
    into one (nb+1)-wide lane vector so each step costs a single
    accumulate into the column buffer (halving the update traffic of the
    naive two-slice-add schedule).
    """
    nb = a.shape[-1]
    cols = jnp.zeros(a.shape[:-1] + (2 * nb,), U32)
    z1 = jnp.zeros(a.shape[:-1] + (1,), U32)
    for i in range(nb):
        prod = a[..., i:i + 1] * b               # exact uint32 products
        row = (jnp.concatenate([prod & DMASK, z1], axis=-1)
               + jnp.concatenate([z1, prod >> np.uint32(DBITS)], axis=-1))
        cols = cols.at[..., i:i + nb + 1].add(row)   # lo at c, hi at c+1
    return cols


def vnc_cols_skew(a, b):
    """(..., nb) x2 uint32 digits -> (..., 2nb) lazy cols (skew form)."""
    nb = a.shape[-1]
    prod = a[..., :, None] * b[..., None, :]     # (..., nb, nb) exact
    lo = skew(prod & DMASK).sum(axis=-2)         # (..., 2nb-1)
    hi = skew(prod >> np.uint32(DBITS)).sum(axis=-2)
    zeros1 = jnp.zeros(a.shape[:-1] + (1,), U32)
    cols = jnp.concatenate([lo, zeros1], axis=-1)
    return cols + jnp.concatenate([zeros1, hi], axis=-1)   # hi -> c+1
