"""Tiny autotune-over-block-shapes cache for the DoT kernel family.

The only block-shape degree of freedom in these kernels is the batch
tile TB (the digit axis is never split), so "autotuning" is a 1-D sweep:
time the compiled kernel at each power-of-two candidate tile and cache
the winner, keyed by ``(op, m, batch, digit_bits)``.

Off by default -- the tiling heuristic is deterministic and good enough
for tests/CI; call ``repro.api.configure(autotune=True)`` (or set the
deprecated ``REPRO_AUTOTUNE=1`` alias) to let benchmarks measure.  The
cache is process-local (kernel specializations are jit-cached anyway, so
a sweep costs one compile per candidate, once per key).

Usage from an ops wrapper (tile selection must happen OUTSIDE jit so the
sweep can run real timed calls):

    heur = tiling.batch_tile(m, batch, budget=...)
    tb = autotune.pick_tile("dot_mul", (m, batch, 16), heur, batch,
                            run=lambda t: _call(a, b, t, ...))
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.kernels.common import tiling

_CACHE: dict = {}
_COUNTERS = {"hits": 0, "misses": 0}


def enabled() -> bool:
    """configure(autotune=...) wins; the deprecated REPRO_AUTOTUNE env
    var is its alias; default off (see repro/config.py)."""
    from repro import config as _rc
    return _rc.autotune_enabled()


def clear_cache() -> None:
    _CACHE.clear()
    for k in _COUNTERS:
        _COUNTERS[k] = 0


def cache_summary() -> dict:
    """{(op, m, batch, digit_bits): best_tile} for docs/benchmark dumps."""
    return dict(_CACHE)


def cache_stats() -> dict:
    """Hit/miss counters + entry count (repro.api.cache_stats feed).
    Hits/misses only tick when autotuning is enabled (a disabled call
    answers from the heuristic, touching no cache)."""
    return dict(_COUNTERS, entries=len(_CACHE))


def candidate_tiles(heuristic: int, batch: int,
                    max_tile: int = tiling.DEFAULT_MAX_TILE) -> list[int]:
    """Power-of-two tiles up to max_tile (and the heuristic itself)."""
    cands = {heuristic}
    t = tiling.MIN_TILE
    while t <= max_tile:
        cands.add(min(t, max(tiling.MIN_TILE, batch)))
        t *= 2
    return sorted(cands)


def pick_tile(op: str, key: tuple, heuristic: int, batch: int,
              run: Optional[Callable[[int], object]] = None,
              iters: int = 3,
              max_tile: int = tiling.DEFAULT_MAX_TILE) -> int:
    """Best batch tile for (op, *key); the heuristic unless autotuning.

    ``key`` must cover EVERYTHING that changes the compiled kernel
    besides the tile (m, batch, digit_bits, interpret flag, and any
    kernel-variant knobs like kara_mul's threshold/base_mode) -- a tile
    tuned for one variant must not be reused for another.  ``max_tile``
    caps the sweep at the kernel's own VMEM-derived tile ceiling so the
    autotuner never times (or caches) a tile the budget analysis
    excludes.  ``run(tb)`` executes the kernel at tile tb on
    representative inputs; exceptions from a candidate (e.g. VMEM
    overflow on real hardware) disqualify it.
    """
    if run is None or not enabled():
        return heuristic
    try:
        if not jax.core.trace_state_clean():
            return heuristic        # inside an outer trace: no timed sweeps
    except AttributeError:
        pass
    full_key = (op,) + tuple(key)
    if full_key in _CACHE:
        _COUNTERS["hits"] += 1
        return _CACHE[full_key]
    _COUNTERS["misses"] += 1
    best, best_dt = heuristic, float("inf")
    for tb in candidate_tiles(heuristic, batch, max_tile=max_tile):
        try:
            jax.block_until_ready(run(tb))          # compile + warm
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(run(tb))
            dt = (time.perf_counter() - t0) / iters
        except Exception:  # noqa: BLE001 - candidate disqualified
            continue
        if dt < best_dt:
            best, best_dt = tb, dt
    _CACHE[full_key] = best
    return best
