"""Batch-tile heuristics and VMEM budgeting shared by every kernel wrapper.

Each DoT kernel owns a (TB, m)-shaped block of every operand in VMEM; the
only tunable is TB, the batch tile.  The heuristic keeps the kernel's live
working set inside a fixed fraction of VMEM:

    TB * m * live_u32_arrays * 4 bytes  <=  TARGET_WORKING_SET_BYTES

``live_u32_arrays`` is the per-kernel count of simultaneously-live
(TB, ~m) uint32 arrays (operands + accumulator + normalize temps), a
static property of the kernel body.  The previous per-ops magic numbers
(64k/32k/16k words) were exactly this formula with live = 6 / 12 / 24;
they are now stated as such in one place.

The heuristic is the default; ``common.autotune`` can override it with a
measured tile when REPRO_AUTOTUNE is set (see that module).
"""
from __future__ import annotations

VMEM_BYTES = 16 * 1024 * 1024          # per-core VMEM on current TPUs
TARGET_WORKING_SET_BYTES = 3 * VMEM_BYTES // 32   # ~1.5 MB: leave room for
#   double-buffered input/output blocks and compiler temps.

MIN_TILE = 8                            # one VPU sublane group
DEFAULT_MAX_TILE = 512


def budget_words(live_u32_arrays: int,
                 working_set_bytes: int = TARGET_WORKING_SET_BYTES) -> int:
    """Max TB*m uint32 words per live array under the working-set target."""
    return working_set_bytes // (4 * max(1, live_u32_arrays))


def batch_tile(m: int, batch: int, *, budget: int,
               max_tile: int = DEFAULT_MAX_TILE,
               min_tile: int = MIN_TILE) -> int:
    """Heuristic batch tile for a kernel over (batch, m) digit arrays."""
    tb = max(min_tile, min(max_tile, budget // max(min_tile, m)))
    return min(tb, max(min_tile, batch))
