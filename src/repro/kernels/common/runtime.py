"""Backend/runtime helpers shared by every kernel ops wrapper."""
from __future__ import annotations

import jax


def auto_interpret(interpret: bool | None) -> bool:
    """Resolve the interpret flag: explicit value wins, else interpret
    mode on CPU (bit-exact kernel validation) and compiled on TPU."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret
