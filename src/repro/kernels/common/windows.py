"""Exponent bit -> k-ary window packing, shared by every modexp ladder.

The ONE home of the packing (the jnp/Barrett ladders in core/modular.py
and the fused-ladder wrapper in kernels/dot_modmul/ops.py all call it,
so every backend walks the identical schedule).  Lives in
kernels/common -- pure jnp, no Pallas import -- because core must not
depend on the kernel packages (which pull in jax.experimental.pallas)
for a plain jnp/barrett exponentiation.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32


def exponent_windows(exp_bits, window: int):
    """(..., nbits) MSB-first exponent bits -> (..., nwin) k-ary window
    values (each < 2**window), MSB-first, left-padded with zero bits so
    window boundaries align with the LEAST significant bit.
    """
    w = int(window)
    if w < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    eb = jnp.asarray(exp_bits, U32)
    nbits = eb.shape[-1]
    nwin = -(-nbits // w)
    pad = nwin * w - nbits
    if pad:
        eb = jnp.concatenate(
            [jnp.zeros(eb.shape[:-1] + (pad,), U32), eb], axis=-1)
    weights = jnp.asarray([1 << (w - 1 - k) for k in range(w)], U32)
    return jnp.sum(eb.reshape(eb.shape[:-1] + (nwin, w)) * weights, axis=-1)
