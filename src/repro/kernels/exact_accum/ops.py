"""Jit'd wrappers for the exact-accumulation kernels.

Arrays of any shape are flattened to (batch, n) tiles; digit planes are
(L, ...) leading-axis so cross-replica psum reduces contiguous planes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exact_accum import DEFAULT, ExactAccumConfig
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.exact_accum import kernel as K

U32 = jnp.uint32
_N = 256   # lane tile


def _as2d(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _N
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _N), pad


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def encode(x, cfg: ExactAccumConfig = DEFAULT, interpret=None):
    """f32 (...) -> uint32 (L, ceil(size/N), N) digit planes."""
    interpret = _auto_interpret(interpret)
    x2, _ = _as2d(x)
    b, n = x2.shape
    tb = min(64, b)
    padb = (-b) % tb
    if padb:
        x2 = jnp.pad(x2, ((0, padb), (0, 0)))
    grid = x2.shape[0] // tb
    return K.make_encode(cfg, tb, n, grid, interpret)(x2)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def accumulate(acc, digits, interpret=None):
    """acc += digits (deferred-carry; acc donated/aliased)."""
    interpret = _auto_interpret(interpret)
    L, b, n = acc.shape
    tb = min(64, b)
    grid = b // tb if b % tb == 0 else None
    if grid is None:
        return acc + digits          # ragged fallback
    return K.make_accum(L, tb, n, grid, interpret)(acc, digits)


@functools.partial(jax.jit, static_argnames=("cfg", "shape", "interpret"))
def finalize(acc, cfg: ExactAccumConfig = DEFAULT, shape=None, interpret=None):
    """digit planes -> f32, carries resolved; optionally reshaped."""
    interpret = _auto_interpret(interpret)
    L, b, n = acc.shape
    tb = min(64, b)
    padb = (-b) % tb
    if padb:
        acc = jnp.pad(acc, ((0, 0), (0, padb), (0, 0)))
    grid = acc.shape[1] // tb
    y = K.make_finalize(cfg, tb, n, grid, interpret)(acc)[:b]
    flat = y.reshape(-1)
    if shape is not None:
        flat = flat[: int(np.prod(shape))].reshape(shape)
    return flat
