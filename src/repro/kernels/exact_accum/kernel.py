"""Pallas TPU kernels for exact deferred-carry gradient accumulation.

Three fused kernels (core/exact_accum.py is the jnp oracle):
  encode_kernel     : f32 tile -> L uint32 digit planes (quantize + split +
                      two's-complement sign extension) in one VMEM pass.
  accum_kernel      : acc += digits, carry-free (input/output aliased; the
                      deferred-carry inner loop of microbatch accumulation).
  finalize_kernel   : carry-resolve (2 deferred passes + Kogge-Stone tail)
                      + two's-complement decode back to f32.

Digit planes are laid out (L, batch_tile, n) so each plane is a clean
(8, 128)-aligned VPU tile; L is tiny (4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.exact_accum import ExactAccumConfig

U32 = jnp.uint32
I32 = jnp.int32
F32 = jnp.float32


def encode_kernel(x_ref, d_ref, *, cfg: ExactAccumConfig):
    x = x_ref[...]
    q = jnp.round(jnp.clip(x.astype(F32), -cfg.clip, cfg.clip)
                  * (2.0 ** cfg.frac_bits)).astype(I32)
    u = q.astype(U32)
    r = cfg.radix_bits
    mask = np.uint32((1 << r) - 1)
    neg = q < 0
    neg_fill = jnp.where(neg, mask, np.uint32(0))
    for k in range(cfg.num_limbs):
        lo_bit = r * k
        if lo_bit < 32:
            d = u >> np.uint32(lo_bit)
            if lo_bit + r > 32:
                ext_bits = lo_bit + r - 32
                ext = jnp.where(neg, np.uint32((1 << ext_bits) - 1),
                                np.uint32(0))
                d = d | (ext << np.uint32(32 - lo_bit))
            d_ref[k, :, :] = d & mask
        else:
            d_ref[k, :, :] = neg_fill


def accum_kernel(acc_ref, d_ref, out_ref):
    # deferred-carry accumulate: one VPU add per plane, NO carry handling.
    out_ref[...] = acc_ref[...] + d_ref[...]


def finalize_kernel(acc_ref, y_ref, *, cfg: ExactAccumConfig):
    acc = acc_ref[...]                       # (L, TB, n)
    r = np.uint32(cfg.radix_bits)
    mask = np.uint32((1 << cfg.radix_bits) - 1)
    L = cfg.num_limbs
    # two deferred-carry passes along the (leading) limb axis
    for _ in range(2):
        carry = acc >> r
        low = acc & mask
        shifted = jnp.concatenate(
            [jnp.zeros_like(carry[:1]), carry[:-1]], axis=0)
        acc = low + shifted
    # Kogge-Stone tail (L is tiny: unrolled pairwise combine)
    g = (acc >> r).astype(U32)
    low = acc & mask
    p = (low == mask).astype(U32)
    d = 1
    while d < L:
        g_sh = jnp.concatenate([jnp.zeros_like(g[:d]), g[:-d]], axis=0)
        p_sh = jnp.concatenate([jnp.ones_like(p[:d]), p[:-d]], axis=0)
        g = g | (p & g_sh)
        p = p & p_sh
        d *= 2
    c = jnp.concatenate([jnp.zeros_like(g[:1]), g[:-1]], axis=0)
    low = (low + c) & mask

    # decode two's complement: complement negatives in the integer domain
    # (f32 cannot represent 2**(rL) - |v| minus 2**(rL) without losing |v|).
    neg = (low[-1] >> np.uint32(cfg.radix_bits - 1)) & np.uint32(1)
    comp = mask - low
    comp = jnp.concatenate(
        [(comp[:1] + np.uint32(1)), comp[1:]], axis=0)
    # resolve the +1 ripple through the complemented digits (KS tail)
    g2 = (comp >> r).astype(U32)
    low2 = comp & mask
    p2 = (low2 == mask).astype(U32)
    d = 1
    while d < L:
        g_sh = jnp.concatenate([jnp.zeros_like(g2[:d]), g2[:-d]], axis=0)
        p_sh = jnp.concatenate([jnp.ones_like(p2[:d]), p2[:-d]], axis=0)
        g2 = g2 | (p2 & g_sh)
        p2 = p2 & p_sh
        d *= 2
    c2 = jnp.concatenate([jnp.zeros_like(g2[:1]), g2[:-1]], axis=0)
    mag = (low2 + c2) & mask
    digits = jnp.where(neg[None] == 1, mag, low)
    val = jnp.zeros(low.shape[1:], F32)
    for k in reversed(range(L)):
        val = val * float(1 << cfg.radix_bits) + digits[k].astype(F32)
    val = jnp.where(neg == 1, -val, val)
    y_ref[...] = val * (2.0 ** -cfg.frac_bits)


def make_encode(cfg, tb, n, grid, interpret):
    return pl.pallas_call(
        functools.partial(encode_kernel, cfg=cfg),
        grid=(grid,),
        in_specs=[pl.BlockSpec((tb, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((cfg.num_limbs, tb, n), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((cfg.num_limbs, grid * tb, n), U32),
        interpret=interpret,
    )


def make_accum(L, tb, n, grid, interpret):
    spec = pl.BlockSpec((L, tb, n), lambda i: (0, i, 0))
    return pl.pallas_call(
        accum_kernel,
        grid=(grid,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((L, grid * tb, n), U32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )


def make_finalize(cfg, tb, n, grid, interpret):
    return pl.pallas_call(
        functools.partial(finalize_kernel, cfg=cfg),
        grid=(grid,),
        in_specs=[pl.BlockSpec((cfg.num_limbs, tb, n), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * tb, n), F32),
        interpret=interpret,
    )
