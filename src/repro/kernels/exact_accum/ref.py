"""Pure-jnp oracle for the exact-accumulation kernels (core.exact_accum)."""
import jax.numpy as jnp

from repro.core import exact_accum as EA


def encode_ref(x, cfg=EA.DEFAULT, n=256):
    """Matches ops.encode layout: (L, ceil(size/n), n)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    d = EA.encode(flat.reshape(-1, n), cfg)        # (B, n, L)
    return jnp.moveaxis(d, -1, 0)                   # (L, B, n)


def finalize_ref(acc, cfg=EA.DEFAULT, shape=None):
    import numpy as np
    norm = EA.normalize(jnp.moveaxis(acc, 0, -1), cfg)
    y = EA.decode(norm, cfg).reshape(-1)
    if shape is not None:
        y = y[: int(np.prod(shape))].reshape(shape)
    return y
