"""Python-int oracle for the fused long-division kernel.

Python ints ARE the reference bignum implementation (see core/limbs.py):
the oracle computes divmod() exactly, host-side, digit-for-digit
comparable with the kernel output.  Deliberately independent of ALL jnp
code so a kernel bug and a core/div.py bug cannot cancel.
"""
from __future__ import annotations

import numpy as np

from repro.core import limbs as L

DIGIT_BITS = 16


def divmod_ref(a_digits: np.ndarray, b_digits: np.ndarray):
    """(batch, na), (batch, nb) digit arrays -> ((batch, na), (batch, nb))
    exact quotient/remainder digits (b == 0 rows raise, as undefined)."""
    a_digits = np.asarray(a_digits)
    b_digits = np.asarray(b_digits)
    na = a_digits.shape[-1]
    nb = b_digits.shape[-1]
    qs, rs = [], []
    for i in range(a_digits.shape[0]):
        x = L.limbs_to_int(a_digits[i], DIGIT_BITS)
        y = L.limbs_to_int(b_digits[i], DIGIT_BITS)
        q, r = divmod(x, y)
        qs.append(L.int_to_limbs(q, na, DIGIT_BITS))
        rs.append(L.int_to_limbs(r, nb, DIGIT_BITS))
    return np.stack(qs), np.stack(rs)
