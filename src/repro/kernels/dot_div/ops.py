"""Jit'd wrappers for the fused Knuth-D long-division Pallas kernel.

Mirrors dot_mul/ops: interpret mode auto-selected on CPU, batch padded
to the tile size and trimmed after the call, tile chosen outside jit via
kernels/common (heuristic by default, measured sweep under
REPRO_AUTOTUNE=1).

The Knuth normalization lives HERE, not in the kernel: the per-element
shift s (pushing the divisor's top bit to the array top) is
data-dependent, so it runs as plain jnp gather/shift ops around the
launch while the kernel keeps fully static control flow.  The dividend
is widened by the divisor width so the shift cannot overflow, the
kernel divides the shifted pair, and the remainder is un-shifted on the
way out (the quotient needs no fixup: scaling numerator and denominator
by 2**s preserves it exactly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import div as coredivi
from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.dot_div import kernel as K
from repro.resilience import inject as _inject

U32 = jnp.uint32
DIGIT_BITS = 16


def _heuristic_tile(w: int, batch: int) -> int:
    return tiling.batch_tile(
        w, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def _call(a_s, b_norm, tb: int, interpret: bool):
    batch, wa = a_s.shape
    nb = b_norm.shape[-1]
    pad = (-batch) % tb
    if pad:
        a_s = jnp.pad(a_s, ((0, pad), (0, 0)))
        b_norm = jnp.pad(b_norm, ((0, pad), (0, 0)))
        # padded lanes divide by 0; the kernel masks b_top so they only
        # produce (discarded) garbage, never a fault
    grid = a_s.shape[0] // tb
    q, r = K.make_call(tb, wa, nb, grid, interpret)(a_s, b_norm)
    return q[:batch], r[:batch]


def dot_divmod_digits(a_digits, b_digits, interpret=None):
    """(batch, na) // (batch, nb) radix-2**16 digit arrays ->
    ((batch, na) quotient, (batch, nb) remainder), exact.

    b == 0 lanes are undefined.  na*nb digit steps run fused in VMEM;
    use the reciprocal path (core/div) for operand sizes above the
    DIV_DISPATCH threshold.
    """
    _inject.fire("kernels/dot_div")
    a = jnp.asarray(a_digits, U32)
    b = jnp.asarray(b_digits, U32)
    batch, na = a.shape
    nb = b.shape[-1]
    s = jnp.uint32(nb * DIGIT_BITS) - coredivi.bit_length_digits(b)
    b_norm = coredivi.shift_left_bits(b, s)
    a_s = coredivi.shift_left_bits(
        jnp.pad(a, ((0, 0), (0, nb))), s)              # (batch, na+nb)
    interpret = _auto_interpret(interpret)
    tb = autotune.pick_tile(
        "dot_div", (na + nb, nb, batch, 16, interpret),
        _heuristic_tile(na + nb, batch), batch,
        run=lambda t: _call(a_s, b_norm, t, interpret), max_tile=K.MAX_TILE)
    q, r_norm = _call(a_s, b_norm, tb, interpret)
    r = coredivi.shift_right_bits(r_norm, s)
    return q[:, :na], r


def dot_divmod_limbs32(a_limbs, b_limbs, interpret=None):
    """(batch, ma) // (batch, mb) uint32 saturated limbs -> (q, r) limbs,
    with radix conversion at entry/exit (same contract as
    core/div.divmod_limbs32)."""
    from repro.core.mul import join_digits, split_digits
    ma = a_limbs.shape[-1]
    mb = b_limbs.shape[-1]
    a_d = split_digits(jnp.asarray(a_limbs, U32), DIGIT_BITS)
    b_d = split_digits(jnp.asarray(b_limbs, U32), DIGIT_BITS)
    q_d, r_d = dot_divmod_digits(a_d, b_d, interpret)
    return (join_digits(q_d, DIGIT_BITS, ma),
            join_digits(r_d, DIGIT_BITS, mb))
