"""Fused Pallas TPU kernel for batched schoolbook (Knuth-D) division.

One program owns a (TB, wa) dividend block and a (TB, nb) normalized
divisor block in VMEM and runs the FULL long division there: wa
digit-serial steps, each one trial-quotient estimate + multiply-subtract
+ branch-free add-back, with the (TB, nb+1) partial remainder never
leaving vregs.  The division twin of dot_modmul's fused CIOS loop (the
digit-serial dependency chain is inherent; everything inside a step is
full-width VPU work over the batch tile).

Inputs are PRE-NORMALIZED by the ops wrapper (Knuth's condition, pushed
to the array top so every trial position is static):

  * b_norm = b << s with the top BIT of the array set, so the leading
    digit b_top >= D/2 for every lane -- the bound that makes the
    two-digit trial estimate q_hat = (r1*D + r0) / b_top off by AT MOST
    +2 (Knuth TAoCP 4.3.1 Theorem B), never low.
  * a_s = a << s (widened by nb digits so the shift cannot overflow).
    q = a_s / b_norm is exactly a / b; r_norm = a_s mod b_norm is
    (a mod b) << s, un-shifted by the wrapper.

In-kernel schedule per step t (MSB-first over dividend digits):
  P1 shift-in   : r <- r*D + a_digit (static slice concat; r < b*D).
  P2 estimate   : q_hat from the top two remainder digits vs b_top
                  (one uint32 divide per lane -- the only divide in the
                  whole subsystem's inner loops).
  P3 mul-sub    : r <- r - q_hat*b via lazy lo/hi products, ONE
                  normalize, radix-complement subtract; the carry out
                  of the top digit flags a negative result.
  P4 add-back   : two unrolled masked corrections (q_hat -= 1,
                  r += b_norm); Knuth's bound proves two always suffice.

b == 0 lanes are undefined (the wrapper documents this; the estimate's
divide-by-zero is masked by substituting b_top = 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common.carry import normalize_static

U32 = jnp.uint32
DMASK = np.uint32(0xFFFF)
DBITS = np.uint32(16)

# Live (TB, ~nb) u32 arrays per step: a, b, q columns, partial remainder,
# lazy product pair, complement temps, normalize temps.
LIVE_U32_ARRAYS = 16
MAX_TILE = 256


def _sub_flag(r, t):
    """(r - t mod D**w, ge) on (TB, w) normalized digit blocks.

    Radix-complement add over w+1 digits; the top digit of the
    normalized sum is 1 iff r >= t (no borrow).
    """
    tb, w = r.shape
    comp = DMASK - t
    s = jnp.concatenate([r + comp, jnp.zeros((tb, 1), U32)], axis=1)
    s = normalize_static(s.at[:, 0:1].add(1), 16, bound=(1 << 17) + 2)
    return s[:, :w], s[:, w:w + 1]


def div_step(r, ain, b, b_top):
    """One Knuth-D step: returns (new remainder, quotient digit).

    r: (TB, nb+1) partial remainder < b_norm; ain: (TB, 1) next dividend
    digit; b: (TB, nb) normalized divisor; b_top: (TB, 1) leading digit
    (>= D/2, or the masked stand-in 1 for zero divisors).
    """
    tb, nb1 = r.shape
    nb = nb1 - 1
    # P1: r*D + ain.  r < b < D**nb so the dropped top digit is 0.
    r = jnp.concatenate([ain, r[:, :nb]], axis=1)
    # P2: two-digit trial estimate, clamped to the digit range.
    num = (r[:, nb:nb + 1] << DBITS) | r[:, nb - 1:nb]
    qh = jnp.minimum(num // b_top, DMASK)
    # P3: r - qh*b with lazy products and one static resolve.
    prod = qh * b                                   # (TB, nb) exact uint32
    t = jnp.zeros((tb, nb + 1), U32)
    t = t.at[:, :nb].add(prod & DMASK)
    t = t.at[:, 1:nb + 1].add(prod >> DBITS)
    t = normalize_static(t, 16, bound=1 << 17)      # qh*b, < D**(nb+1)
    u, ge = _sub_flag(r, t)
    # P4: at most two add-backs (Knuth: qh <= q + 2, never < q).
    for _ in range(2):
        fix = (ge == 0).astype(U32)                 # (TB, 1)
        qh = qh - fix
        # lazy add + one resolve; the carry out of digit nb+1 means the
        # offset representation wrapped, i.e. r is non-negative again.
        add = jnp.concatenate(
            [u + jnp.pad(b * fix, ((0, 0), (0, 1))),
             jnp.zeros((tb, 1), U32)], axis=1)
        add = normalize_static(add, 16, bound=(1 << 17) + 1)
        u = jnp.where(fix == 1, add[:, :nb + 1], u)
        ge = jnp.where(fix == 1, add[:, nb + 1:nb + 2], ge)
    return u, qh


def make_div_kernel(wa: int, nb: int):
    """Kernel body for a (TB, wa) dividend over a (TB, nb) divisor."""

    def div_kernel(a_ref, b_ref, q_ref, r_ref):
        a = a_ref[...]                              # (TB, wa) shifted dividend
        b = b_ref[...]                              # (TB, nb) normalized
        tb = a.shape[0]
        b_top = jnp.maximum(b[:, nb - 1:nb], 1)     # mask zero divisors
        r = jnp.zeros((tb, nb + 1), U32)
        qcols = []
        for t in range(wa):                         # MSB-first digit serial
            r, qh = div_step(r, a[:, wa - 1 - t:wa - t], b, b_top)
            qcols.append(qh)
        q_ref[...] = jnp.concatenate(qcols[::-1], axis=1)
        r_ref[...] = r[:, :nb]

    return div_kernel


@functools.lru_cache(maxsize=64)
def make_call(batch_tile: int, wa: int, nb: int, grid: int, interpret: bool):
    """pallas_call for the fused long division.

    Inputs: a_s (grid*TB, wa), b_norm (grid*TB, nb).  Outputs: the
    little-endian quotient (grid*TB, wa) and the still-shifted remainder
    (grid*TB, nb).
    """
    return pl.pallas_call(
        make_div_kernel(wa, nb),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, wa), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, nb), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((batch_tile, wa), lambda i: (i, 0)),
                   pl.BlockSpec((batch_tile, nb), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((grid * batch_tile, wa), U32),
                   jax.ShapeDtypeStruct((grid * batch_tile, nb), U32)],
        interpret=interpret,
    )
