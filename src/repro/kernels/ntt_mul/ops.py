"""Jit'd wrappers for the fused NTT multiply kernel + CRT recombination.

Entry points follow the kernel-family conventions (interpret mode
auto-selected on CPU, batch padded to the tile and trimmed, tile chosen
outside jit).  The pipeline per multiply:

  split to radix-2**16 digits, zero-pad to N = next_pow2(2 * ndigits)
  one fused kernel launch PER PRIME  ->  residue arrays mod p_i
  Garner mixed-radix CRT (plain jnp -- elementwise Montgomery ops)
  digit-column accumulation + ONE deferred-carry resolve
  (kernels/common/carry.normalize_static)

Prime count: 2 primes give a CRT modulus ~2**56 -- exact for operands to
~2**24 digits (hundreds of megabits), far past the 64K-bit design point;
3 primes (~2**86) are kept selectable for validation and future wider
digit radices.  ``_resolve_nprimes`` enforces the coefficient bound
``ndigits * (2**16 - 1)**2 < prod(primes)`` at trace time either way.

Garner with ascending primes p1 < p2 < p3 never needs a residue
pre-reduction (r1 < p1 < p2, t2 < p2 < p3), and its mixed-radix digits
(v = r1 + p1*t2 + p1*p2*t3) decompose into 16-bit half products against
the HOST-known constant digits of p1 and p1*p2 -- every partial fits
uint32, lazily accumulated into product columns with a worst case of 26
terms per column (< 2**21, see test_ntt_mul's bound check) before the
single static carry resolve.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import autotune, tiling
from repro.kernels.common.carry import normalize_static
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.ntt_mul import kernel as K
from repro.resilience import inject as _inject

U32 = jnp.uint32
R = 1 << K.R_BITS
DIGIT_BITS = 16
DMASK = np.uint32(0xFFFF)

# Worst-case lazy terms landing on one CRT output column (2 from r1's
# lo/hi, 8 from t2 x p1's 2x2 half products, 16 from t3 x (p1*p2)'s 2x4),
# each < 2**16: the bound fed to the single normalize_static resolve.
CRT_COLUMN_TERMS = 26


def next_pow2(x: int) -> int:
    n = 1
    while n < x:
        n *= 2
    return n


def coefficient_bound(ndigits: int) -> int:
    """Max product-polynomial coefficient: ndigits digit pairs, each
    < (2**16 - 1)**2."""
    return ndigits * (DMASK.item() ** 2)


def _resolve_nprimes(ndigits: int, nprimes: int | None) -> int:
    """Validate/choose the CRT prime-set size for an operand width."""
    if nprimes is None:
        from repro.configs.dot_bignum import MUL_DISPATCH
        nprimes = MUL_DISPATCH.ntt_primes
    if nprimes not in (2, 3):
        raise ValueError(f"nprimes must be 2 or 3, got {nprimes!r}")
    m = 1
    for p in K.PRIMES[:nprimes]:
        m *= p
    if coefficient_bound(ndigits) >= m:
        raise ValueError(
            f"{ndigits} digits overflow the {nprimes}-prime CRT modulus "
            f"(need prod(primes) > ndigits * (2**16-1)**2)")
    return nprimes


# ---------------------------------------------------------------------------
# Host-side twiddle tables (cached per (prime, N); Montgomery domain).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def twiddle_tables(p: int, n: int) -> tuple[np.ndarray, np.ndarray]:
    """(forward, inverse) twiddles, each (log2 N, N//2) uint32, w*R mod p.

    Forward stage s (DIF, half-size N >> (s+1)) uses powers of
    w_m = w**(N/m) with m the stage's block size; inverse stage s (DIT,
    half-size 2**s) uses powers of w_m**-1.  Rows are front-filled and
    zero-padded; the kernel slices the live prefix statically.
    """
    w = pow(K.GENERATOR, (p - 1) // n, p)
    winv = pow(w, -1, p)
    stages = n.bit_length() - 1
    wf = np.zeros((stages, max(1, n // 2)), np.uint32)
    wi = np.zeros((stages, max(1, n // 2)), np.uint32)
    for s in range(stages):
        for tbl, root, ln in ((wf, w, n >> (s + 1)), (wi, winv, 1 << s)):
            wm = pow(root, n // (2 * ln), p)
            cur = 1
            for j in range(ln):
                tbl[s, j] = cur * R % p
                cur = cur * wm % p
    return wf, wi


# ---------------------------------------------------------------------------
# Prepared operands: the forward NTT of a FIXED operand is a
# precomputation exactly like twiddles (van der Hoeven & Lecerf), so the
# repeat-multiply-by-a-constant consumers (Newton reciprocal levels,
# divmod_const, Barrett's mu and n, base-conversion chunk constants)
# never pay for the same transform twice.  Cached host-side in a bounded
# LRU keyed by (value, prime set, N) with hit/miss/eviction counters
# (repro.api.cache_stats); capacity via configure(ntt_cache_entries=...),
# 0 disables the prepared path entirely (the A/B switch benchmarks use).
# ---------------------------------------------------------------------------

DEFAULT_CACHE_ENTRIES = 64

_prepared_cache: "collections.OrderedDict[tuple, tuple]" = \
    collections.OrderedDict()
_prepared_counters = {"hits": 0, "misses": 0, "evictions": 0}


def operand_cache_capacity() -> int:
    """LRU entry cap for the prepared-operand cache (0: path disabled)."""
    from repro import config as _rc
    cap = _rc.resolve("ntt_cache_entries")
    if cap is None:
        return DEFAULT_CACHE_ENTRIES
    cap = int(cap)
    if cap < 0:
        raise ValueError(f"ntt_cache_entries must be >= 0, got {cap}")
    return cap


def operand_cache_stats() -> dict:
    """Counters + occupancy for repro.api.cache_stats()."""
    return dict(_prepared_counters,
                entries=len(_prepared_cache),
                capacity=operand_cache_capacity())


def clear_operand_cache() -> None:
    _prepared_cache.clear()
    for k in _prepared_counters:
        _prepared_counters[k] = 0


def _host_ntt_forward(digits: np.ndarray, p: int) -> np.ndarray:
    """Exact uint64 replica of the kernel's DIF forward transform for one
    (N,) natural-order digit vector mod p (output order bit-reversed,
    NORMAL domain -- matching what ntt_forward leaves for the pointwise
    product).  p < 2**30, so every (u + p - v) % p * tw product stays
    below 2**60: exact in uint64."""
    n = digits.shape[-1]
    x = digits.astype(np.uint64) % p
    w = pow(K.GENERATOR, (p - 1) // n, p)
    for s in range(n.bit_length() - 1):
        ln = n >> (s + 1)
        wm = pow(w, n // (2 * ln), p)
        tw = np.empty((ln,), np.uint64)
        cur = 1
        for j in range(ln):
            tw[j] = cur
            cur = cur * wm % p
        y = x.reshape(-1, 2, ln)
        u, v = y[:, 0, :], y[:, 1, :]
        x = np.stack([(u + v) % p, (u + p - v) % p * tw % p],
                     axis=1).reshape(n)
    return x.astype(np.uint32)


def prepared_operand(value: int, n: int, nprimes: int) -> tuple:
    """Per-prime (1, N) forward-NTT rows of a host-known operand value,
    served from the bounded LRU (key: (value, prime set, N) -- same
    value at a different transform length or prime count is a distinct
    entry, so two moduli never share a prepared operand)."""
    key = (value, nprimes, n)
    hit = _prepared_cache.get(key)
    if hit is not None:
        _prepared_cache.move_to_end(key)
        _prepared_counters["hits"] += 1
        return hit
    _prepared_counters["misses"] += 1
    digits = np.array([(value >> (DIGIT_BITS * k)) & 0xFFFF
                       for k in range(n)], np.uint32)
    # the rows MUST be concrete arrays: a caller may hit this miss path
    # while inside an outer jit trace, and without the eager guard the
    # [None, :] below would stage and poison the process-global cache
    # with that trace's tracers (crashing every later caller)
    with jax.ensure_compile_time_eval():
        rows = tuple(jnp.asarray(_host_ntt_forward(digits, p)[None, :])
                     for p in K.PRIMES[:nprimes])
    _prepared_cache[key] = rows
    cap = operand_cache_capacity()
    while len(_prepared_cache) > max(1, cap):
        _prepared_cache.popitem(last=False)
        _prepared_counters["evictions"] += 1
    return rows


# ---------------------------------------------------------------------------
# CRT recombination (plain jnp; reuses the kernel's elementwise mod ops).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _garner_constants(nprimes: int) -> dict:
    """Host-precomputed Montgomery constants for Garner recombination."""
    p1, p2 = K.PRIMES[0], K.PRIMES[1]
    c = {
        "pinv2": (-pow(p2, -1, R)) % R,
        "inv1_mont2": pow(p1, -1, p2) * R % p2,     # mont_mul -> * p1^-1
        "p1_digits": tuple((p1 >> (16 * k)) & 0xFFFF for k in range(2)),
    }
    if nprimes >= 3:
        p3 = K.PRIMES[2]
        q = p1 * p2
        c.update({
            "pinv3": (-pow(p3, -1, R)) % R,
            "p1_mont3": p1 * R % p3,                # mont_mul -> * p1
            "inv12_mont3": pow(q, -1, p3) * R % p3,  # mont_mul -> * q^-1
            "q_digits": tuple((q >> (16 * k)) & 0xFFFF for k in range(4)),
        })
    return c


def crt_combine(residues, out_digits: int):
    """Per-prime residue arrays (..., >= out_digits) -> (..., out_digits)
    normalized radix-2**16 digits of the recombined coefficients.

    Garner: v = r1 + p1*t2 (+ p1*p2*t3), every multiply against the
    host-known constant digits of p1 / p1*p2 as 16-bit half products,
    accumulated lazily and resolved with ONE static carry pass.
    """
    nprimes = len(residues)
    c = _garner_constants(nprimes)
    p2 = K.PRIMES[1]
    r1 = residues[0][..., :out_digits]
    t2 = K.mont_mul(
        K.sub_mod(residues[1][..., :out_digits], r1, p2),
        jnp.full((), np.uint32(c["inv1_mont2"]), U32), p2, c["pinv2"])

    lead = r1.shape[:-1]
    width = out_digits + 8                 # headroom for the top carries
    cols = jnp.zeros(lead + (width,), U32)

    def acc(cols, vals, off):
        return cols.at[..., off:off + out_digits].add(vals)

    def acc_prod(cols, t, const_digits):
        tlo = t & DMASK
        thi = t >> np.uint32(16)
        for k, ck in enumerate(const_digits):
            if ck == 0:
                continue
            for part, o in ((tlo, 0), (thi, 1)):
                prod = part * np.uint32(ck)          # exact in uint32
                cols = acc(cols, prod & DMASK, k + o)
                cols = acc(cols, prod >> np.uint32(16), k + o + 1)
        return cols

    cols = acc(cols, r1 & DMASK, 0)
    cols = acc(cols, r1 >> np.uint32(16), 1)
    cols = acc_prod(cols, t2, c["p1_digits"])
    if nprimes >= 3:
        p3 = K.PRIMES[2]
        c12 = K.add_mod(
            r1, K.mont_mul(t2, jnp.full((), np.uint32(c["p1_mont3"]), U32),
                           p3, c["pinv3"]), p3)
        t3 = K.mont_mul(
            K.sub_mod(residues[2][..., :out_digits], c12, p3),
            jnp.full((), np.uint32(c["inv12_mont3"]), U32), p3, c["pinv3"])
        cols = acc_prod(cols, t3, c["q_digits"])

    norm = normalize_static(cols, DIGIT_BITS,
                            bound=CRT_COLUMN_TERMS << DIGIT_BITS)
    return norm[..., :out_digits]


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------

def _heuristic_tile(n: int, batch: int) -> int:
    return tiling.batch_tile(
        n, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit,
                   static_argnames=("nprimes", "tb", "interpret"))
def _call(a_d, b_d, twiddles, nprimes: int, tb: int, interpret: bool):
    batch, nd = a_d.shape
    n = next_pow2(2 * nd)
    pad_b = (-batch) % tb
    a_p = jnp.pad(a_d, ((0, pad_b), (0, n - nd)))
    b_p = jnp.pad(b_d, ((0, pad_b), (0, n - nd)))
    grid = a_p.shape[0] // tb
    residues = []
    for p, (wf, wi) in zip(K.PRIMES[:nprimes], twiddles):
        r = K.make_call(tb, n, grid, p, interpret)(a_p, b_p, wf, wi)
        residues.append(r[:batch])
    return crt_combine(residues, 2 * nd)


def ntt_mul_digits(a_digits, b_digits, nprimes: int | None = None,
                   interpret=None):
    """(batch, nd) uint32 radix-2**16 digits x2 -> (batch, 2*nd) digits
    of the full product (one fused NTT launch per CRT prime)."""
    a = jnp.asarray(a_digits, U32)
    b = jnp.asarray(b_digits, U32)
    batch, nd = a.shape
    assert b.shape == a.shape
    nprimes = _resolve_nprimes(nd, nprimes)
    interpret = _auto_interpret(interpret)
    n = next_pow2(2 * nd)
    twiddles = tuple(
        tuple(jnp.asarray(t) for t in twiddle_tables(p, n))
        for p in K.PRIMES[:nprimes])
    tb = autotune.pick_tile(
        "ntt_mul", (n, batch, DIGIT_BITS, nprimes, interpret),
        _heuristic_tile(n, batch), batch,
        run=lambda t: _call(a, b, twiddles, nprimes, t, interpret),
        max_tile=K.MAX_TILE)
    return _call(a, b, twiddles, nprimes, tb, interpret)


def ntt_mul_limbs32(a_limbs, b_limbs, nprimes: int | None = None,
                    interpret=None):
    """(batch, m) uint32 saturated limbs x2 -> (batch, 2m) limbs (full
    product), radix-converted at entry/exit (paper sec 3.3)."""
    _inject.fire("kernels/ntt_mul")
    from repro.core import mul as coremul
    m = a_limbs.shape[-1]
    a_d = coremul.split_digits(jnp.asarray(a_limbs, U32), DIGIT_BITS)
    b_d = coremul.split_digits(jnp.asarray(b_limbs, U32), DIGIT_BITS)
    p_d = ntt_mul_digits(a_d, b_d, nprimes, interpret)
    return coremul.join_digits(p_d, DIGIT_BITS, 2 * m)


@functools.partial(jax.jit, static_argnames=("nprimes", "tb", "interpret"))
def _call_prepared(a_d, fb_rows, twiddles, nprimes: int, tb: int,
                   interpret: bool):
    batch, nd = a_d.shape
    n = next_pow2(2 * nd)
    pad_b = (-batch) % tb
    a_p = jnp.pad(a_d, ((0, pad_b), (0, n - nd)))
    grid = a_p.shape[0] // tb
    residues = []
    for p, fb, (wf, wi) in zip(K.PRIMES[:nprimes], fb_rows, twiddles):
        r = K.make_prepared_call(tb, n, grid, p, interpret)(a_p, fb, wf, wi)
        residues.append(r[:batch])
    return crt_combine(residues, 2 * nd)


def ntt_mul_digits_prepared(a_digits, b_value: int,
                            nprimes: int | None = None, interpret=None):
    """(batch, nd) digits x a HOST-KNOWN operand value -> (batch, 2*nd)
    full-product digits, with b's forward transforms served from the
    prepared-operand cache -- each launch runs ONE forward transform
    instead of two.  ``b_value`` must equal the value the caller would
    otherwise pass as a (nd,) digit array (< 2**(16*nd)); the prepared
    rows are runtime (1, N) inputs, so repeat calls share one trace."""
    a = jnp.asarray(a_digits, U32)
    batch, nd = a.shape
    b_value = int(b_value)
    assert 0 <= b_value < 1 << (DIGIT_BITS * nd), \
        "prepared operand wider than the digit array it replaces"
    nprimes = _resolve_nprimes(nd, nprimes)
    interpret = _auto_interpret(interpret)
    n = next_pow2(2 * nd)
    twiddles = tuple(
        tuple(jnp.asarray(t) for t in twiddle_tables(p, n))
        for p in K.PRIMES[:nprimes])
    fb_rows = prepared_operand(b_value, n, nprimes)
    tb = autotune.pick_tile(
        "ntt_mul_prepared", (n, batch, DIGIT_BITS, nprimes, interpret),
        _heuristic_tile(n, batch), batch,
        run=lambda t: _call_prepared(a, fb_rows, twiddles, nprimes, t,
                                     interpret),
        max_tile=K.MAX_TILE)
    return _call_prepared(a, fb_rows, twiddles, nprimes, tb, interpret)


def ntt_mul_limbs32_prepared(a_limbs, b_value: int,
                             nprimes: int | None = None, interpret=None):
    """32-bit limb twin of ntt_mul_digits_prepared: (batch, m) limbs x a
    host-known value < 2**(32m) -> (batch, 2m) limbs."""
    _inject.fire("kernels/ntt_mul")
    from repro.core import mul as coremul
    m = a_limbs.shape[-1]
    a_d = coremul.split_digits(jnp.asarray(a_limbs, U32), DIGIT_BITS)
    p_d = ntt_mul_digits_prepared(a_d, b_value, nprimes, interpret)
    return coremul.join_digits(p_d, DIGIT_BITS, 2 * m)
