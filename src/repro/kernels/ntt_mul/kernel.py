"""Fused NTT multiply Pallas kernel: huge-operand multiplication as pure
lane-parallel butterflies (the limit case of the paper's restructuring).

Above the fused-Karatsuba range the jnp composition pays a quadratic-ish
price exactly where scale matters.  The number-theoretic transform is
the paper's thesis taken to its limit: EVERY butterfly of every stage is
an independent mul/add mod p over the batch x lane grid -- no carry
chains, no shared accumulators, nothing sequential but the log2(N) stage
order (van der Hoeven & Lecerf's "Modular SIMD arithmetic" route to
large-operand throughput).

One launch per CRT prime multiplies a (TB, N) batch tile end to end:

  forward DIF NTT(a), forward DIF NTT(b)   (natural -> bit-reversed)
  pointwise Montgomery product
  inverse DIT NTT                          (bit-reversed -> natural)

The DIF/DIT pairing means NO bit-reversal permutation ever materializes
-- the pointwise product is order-agnostic, so the reversed order lives
only between the transforms.  Twiddle factors are precomputed on the
host (ops.py) in Montgomery form and stay VMEM-resident for the whole
launch; the kernel reads stage s as a static row slice.

Word-size modular arithmetic WITHOUT 64-bit integers: the TPU VPU (and
uint32-only Pallas) cannot widen a 32x32 product, so modmuls run as
Montgomery multiplication (R = 2**32) built from 16-bit half products --
the same lo/hi split the paper uses for simd_mul_lo/hi, applied to the
REDC step.  Primes are < 2**30, so every half-product sum stays in
uint32 (see the bound notes on ``mul32_wide``).  Values stay in the
NORMAL domain throughout: twiddles are stored as w*R mod p, so
``mont_mul(x, w*R) = x*w mod p`` -- only the pointwise product picks up
a stray R**-1, cancelled by folding R**2 into the inverse transform's
1/N scale constant.

CRT recombination of the per-prime residues runs in plain jnp (ops.py)
and funnels into ONE deferred-carry resolve via common/carry.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

U32 = jnp.uint32
R_BITS = 32                      # Montgomery radix R = 2**32

# NTT-friendly primes p = c * 2**k + 1 (ascending -- Garner's mixed-radix
# recombination in ops.py relies on p1 < p2 < p3 so residues never need a
# pre-reduction), all < 2**30 so Montgomery half-product sums fit uint32,
# all with primitive root 3 and 2-adic order >= 2**23 (transform lengths
# to 8M points; a 64K-bit operand needs only N = 2**13).
PRIMES = (167772161,             # 5   * 2**25 + 1
          469762049,             # 7   * 2**26 + 1
          998244353)             # 119 * 2**23 + 1
GENERATOR = 3

# Live (TB, N) uint32 arrays in the fused body: both operands, both
# transforms, the butterfly temps, and the ~8 half-product temps inside a
# Montgomery multiply (those are (TB, N/2)-sized; counted as halves).
LIVE_U32_ARRAYS = 16
MAX_TILE = 128


# ---------------------------------------------------------------------------
# uint32-only modular arithmetic (kernel-safe: branch-free, no uint64).
# ---------------------------------------------------------------------------

def mul32_wide(x, y):
    """Exact 64-bit product of uint32 arrays as a (hi, lo) uint32 pair.

    Schoolbook over 16-bit halves.  ``cross = lh + hl`` can wrap (for
    x, y < 2**31 it cannot, but REDC calls this with a full-range m), so
    the wrap is detected by the unsigned compare and re-injected at bit
    48 -- the standard carry-save emulation of a widening multiply.
    """
    x0 = x & np.uint32(0xFFFF)
    x1 = x >> np.uint32(16)
    y0 = y & np.uint32(0xFFFF)
    y1 = y >> np.uint32(16)
    ll = x0 * y0
    lh = x0 * y1
    hl = x1 * y0
    hh = x1 * y1
    cross = lh + hl                          # may wrap once
    cc = (cross < lh).astype(U32)            # carry out of the cross sum
    lo = ll + ((cross & np.uint32(0xFFFF)) << np.uint32(16))
    cl = (lo < ll).astype(U32)               # carry out of the low word
    hi = hh + (cross >> np.uint32(16)) + (cc << np.uint32(16)) + cl
    return hi, lo


def mont_mul(x, y, p: int, pinv: int):
    """x * y * R**-1 mod p for x, y in [0, p), p < 2**31 (R = 2**32).

    REDC: m = (x*y mod R) * (-p**-1) mod R; t = (x*y + m*p) / R < 2p;
    one branch-free conditional subtract canonicalizes.  The low words
    of x*y and m*p cancel mod R by construction, so their carry into the
    high word is exactly ``lo != 0``.
    """
    hi, lo = mul32_wide(x, y)
    m = lo * np.uint32(pinv)                 # wrapping product mod R
    mp_hi, _ = mul32_wide(m, np.uint32(p))
    t = hi + mp_hi + (lo != 0).astype(U32)
    return jnp.where(t >= np.uint32(p), t - np.uint32(p), t)


def add_mod(a, b, p: int):
    s = a + b                                # < 2p < 2**32
    return jnp.where(s >= np.uint32(p), s - np.uint32(p), s)


def sub_mod(a, b, p: int):
    d = a + (np.uint32(p) - b)
    return jnp.where(d >= np.uint32(p), d - np.uint32(p), d)


# ---------------------------------------------------------------------------
# Radix-2 stages (static Python loop -- log2(N) stages, every butterfly
# lane-parallel).  Twiddle rows are Montgomery-domain, one row per stage.
# ---------------------------------------------------------------------------

def ntt_forward(x, wf, p: int, pinv: int):
    """DIF forward transform, natural order in -> bit-reversed out.

    x: (TB, N); wf: (log2 N, N//2) Montgomery twiddles, stage s using
    wf[s, :N >> (s+1)].  Butterfly: (u, v) -> (u+v, (u-v) * w^j).
    """
    tb, n = x.shape
    for s in range(n.bit_length() - 1):
        ln = n >> (s + 1)                    # half-block size this stage
        y = x.reshape(tb, -1, 2, ln)
        u, v = y[:, :, 0, :], y[:, :, 1, :]
        w = wf[s, :ln][None, None, :]
        x = jnp.stack(
            [add_mod(u, v, p), mont_mul(sub_mod(u, v, p), w, p, pinv)],
            axis=2).reshape(tb, n)
    return x


def ntt_inverse(x, wi, p: int, pinv: int, scale: int):
    """DIT inverse transform, bit-reversed in -> natural out.

    Butterfly: (u, v) -> (u + w^-j v, u - w^-j v); the final Montgomery
    scale constant is N**-1 * R**2 mod p, which both divides by N and
    cancels the R**-1 the pointwise product introduced.
    """
    tb, n = x.shape
    for s in range(n.bit_length() - 1):
        ln = 1 << s
        y = x.reshape(tb, -1, 2, ln)
        u = y[:, :, 0, :]
        t = mont_mul(y[:, :, 1, :], wi[s, :ln][None, None, :], p, pinv)
        x = jnp.stack([add_mod(u, t, p), sub_mod(u, t, p)],
                      axis=2).reshape(tb, n)
    return mont_mul(x, jnp.full((), np.uint32(scale), U32), p, pinv)


def make_ntt_mul_kernel(p: int, pinv: int, scale: int):
    """Fused body: NTT(a), NTT(b), pointwise, inverse -- one launch."""

    def ntt_mul_kernel(a_ref, b_ref, wf_ref, wi_ref, out_ref):
        wf = wf_ref[...]
        wi = wi_ref[...]
        fa = ntt_forward(a_ref[...], wf, p, pinv)
        fb = ntt_forward(b_ref[...], wf, p, pinv)
        c = mont_mul(fa, fb, p, pinv)        # carries one stray R**-1
        out_ref[...] = ntt_inverse(c, wi, p, pinv, scale)

    return ntt_mul_kernel


def make_ntt_mul_prepared_kernel(p: int, pinv: int, scale: int):
    """Fused body with operand b already transformed: NTT(a), pointwise
    against the cached forward residue row, inverse -- one launch that
    skips one of the two forward transforms (~1/3 of transform work).

    ``fb_ref`` is a (1, N) NORMAL-domain forward transform of the fixed
    operand (ops.prepared_operand); the pointwise Montgomery product
    broadcasts it over the batch tile and picks up the same stray R**-1
    as the two-transform kernel, cancelled by the inverse scale.
    """

    def ntt_mul_prepared_kernel(a_ref, fb_ref, wf_ref, wi_ref, out_ref):
        wf = wf_ref[...]
        wi = wi_ref[...]
        fa = ntt_forward(a_ref[...], wf, p, pinv)
        c = mont_mul(fa, fb_ref[...], p, pinv)   # (TB,N)x(1,N) broadcast
        out_ref[...] = ntt_inverse(c, wi, p, pinv, scale)

    return ntt_mul_prepared_kernel


def _derived_constants(n: int, p: int):
    assert n & (n - 1) == 0, "transform length must be a power of two"
    order = (p - 1) & -(p - 1)
    assert n <= order, f"prime {p} has 2-adic order {order} < N={n}"
    pinv = (-pow(p, -1, 1 << R_BITS)) % (1 << R_BITS)
    scale = pow(n, -1, p) * pow(2, 2 * R_BITS, p) % p
    return pinv, scale


@functools.lru_cache(maxsize=64)
def make_prepared_call(batch_tile: int, n: int, grid: int, p: int,
                       interpret: bool):
    """pallas_call for one prime with a prepared operand: (batch, N) a,
    (1, N) forward residue of b, twiddles -> residues."""
    pinv, scale = _derived_constants(n, p)
    stages = n.bit_length() - 1
    return pl.pallas_call(
        make_ntt_mul_prepared_kernel(p, pinv, scale),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((batch_tile, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, n), U32),
        interpret=interpret,
    )


@functools.lru_cache(maxsize=64)
def make_call(batch_tile: int, n: int, grid: int, p: int, interpret: bool):
    """pallas_call for one prime: (batch, N) x2 + twiddles -> residues.

    p, and the constants derived from it here, are trace-time Python
    ints (scalar closures are kernel-safe); the twiddle tables are
    runtime inputs mapped whole into every program (VMEM-resident).
    """
    pinv, scale = _derived_constants(n, p)
    stages = n.bit_length() - 1
    return pl.pallas_call(
        make_ntt_mul_kernel(p, pinv, scale),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((batch_tile, n), lambda i: (i, 0)),
            pl.BlockSpec((batch_tile, n), lambda i: (i, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, n), U32),
        interpret=interpret,
    )
