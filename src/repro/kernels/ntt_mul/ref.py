"""Oracles for the fused NTT multiply kernel.

``ntt_mul_digits_ref`` is the jnp Karatsuba composition (itself
oracle-tested against Python ints in tests/test_mul.py); tests/
test_ntt_mul.py additionally checks digits against Python-int ground
truth directly so a kernel bug and a core/mul.py bug cannot cancel.
``ntt_fwd_ref`` is an O(N**2) Python-int DFT used to pin down the
transform itself (twiddle tables, stage order, bit-reversed layout)
independently of the inverse that would undo a systematic error.
"""
from __future__ import annotations

import numpy as np

from repro.core.mul import mul_karatsuba, mul_limbs32
from repro.kernels.ntt_mul.kernel import GENERATOR


def ntt_mul_digits_ref(a_digits, b_digits):
    return mul_karatsuba(a_digits, b_digits)


def ntt_mul_limbs32_ref(a_limbs, b_limbs):
    return mul_limbs32(a_limbs, b_limbs, method="karatsuba")


def _bit_reverse(i: int, bits: int) -> int:
    out = 0
    for _ in range(bits):
        out = (out << 1) | (i & 1)
        i >>= 1
    return out


def ntt_fwd_ref(x, p: int) -> np.ndarray:
    """Length-N forward NTT mod p by direct evaluation (Python ints),
    returned in the BIT-REVERSED order the DIF kernel produces."""
    n = len(x)
    w = pow(GENERATOR, (p - 1) // n, p)
    nat = [sum(int(x[j]) * pow(w, i * j, p) for j in range(n)) % p
           for i in range(n)]
    bits = n.bit_length() - 1
    return np.array([nat[_bit_reverse(i, bits)] for i in range(n)],
                    np.uint32)
