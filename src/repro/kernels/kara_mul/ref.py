"""Oracles for the fused Karatsuba kernel.

``kara_mul_digits_ref`` is the jnp Karatsuba composition (itself
oracle-tested against Python ints in tests/test_mul.py); the kernel tests
additionally check digits against Python-int ground truth directly so a
kernel bug and a core/mul.py bug cannot cancel.
"""
from repro.core.mul import mul_karatsuba, mul_limbs32


def kara_mul_digits_ref(a_digits, b_digits):
    return mul_karatsuba(a_digits, b_digits)


def kara_mul_limbs32_ref(a_limbs, b_limbs):
    return mul_limbs32(a_limbs, b_limbs, method="karatsuba")
