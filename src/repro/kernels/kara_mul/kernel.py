"""Fused Karatsuba-over-VnC Pallas kernel (one launch, one carry resolve).

The jnp composition in core/mul.py (``mul_karatsuba`` over ``dot_mul``)
pays per recursion level: every node normalizes its product columns with
a data-dependent while-loop, every operand difference runs the
radix-complement machinery of ``digit_sub_abs`` (two more normalizes and
a sign select), and every base case is a separate skew/reduce.  The DoTMP
observation (paper sec 3.3) is that the base-case multiply compounds
through the recursion; this kernel compounds the LAZY-DIGIT idea through
it instead: the whole Karatsuba tree for one batch tile runs inside a
single program, product columns stay deferred-carry uint32 end-to-end,
and exactly ONE static carry resolve happens at the very end.

Three tricks make that possible:

1. **Sum variant + static subtraction.**  We use the
   (a_l + a_h)(b_l + b_h) middle product (sums, not |differences|: no
   data-dependent signs), so the only subtraction is the structural
   ``- p0 - p1`` in the recombination.  A lazy column vector c with
   digits < K is subtracted branch-free by ADDING the per-digit
   complement (K - c[i]): that adds the static constant K * (1 + B +
   ... + B^(L-1)) minus the value of c.  Every such constant is a plain
   Python int computed at trace time; their total CONST is cancelled at
   the end by adding the digits of B^Lp - CONST and letting the known
   B^Lp marker fall off the top -- one constant add, zero selects.

2. **Batched base cases.**  The recursion is resolved at trace time into
   its 3^depth leaf multiplies, whose operands (halves and normalized
   half-sums) are gathered into one (TB, P, nb) tensor; a single VnC row
   loop of nb unrolled steps computes ALL leaf products at once (the
   multiplicative twin of batching independent adds over VPU lanes).

3. **Static overflow accounting.**  Every node tracks a trace-time bound
   on its lazy column digits; the build asserts the final bound stays
   under 2**31, which is what licenses the single end resolve (see
   common/carry.normalize_static).  For 512..4096-bit operands (m = 32..
   256 radix-2**16 digits, threshold 48) the worst bound is ~2**28.

The only per-level carry work left is normalizing the half-SUMS (k+1-wide
operands must be < 2**16 before they can be multiplied exactly in
uint32); that is O(log k) static vector steps on k-wide arrays -- nothing
like the 2m-wide while-loop resolves of the jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common.carry import normalize_static
from repro.kernels.common.vnc import vnc_cols_rows, vnc_cols_skew

U32 = jnp.uint32
DBITS = 16
DMASK = np.uint32((1 << DBITS) - 1)
BASE = 1 << DBITS

# Leaf width in digits.  48 (not a power of two!) so that the k+1-wide
# half-SUM operands of a 2k-wide node stay leaves instead of spawning a
# whole extra subtree: with threshold 32, the 33-wide sums of a 64-digit
# node split again and the leaf count at 2048 bits jumps from 9 to 19 --
# measured ~2.5x slower despite the smaller leaves (padding + leaf-count
# overhead beats the O(n^1.58) win at these widths).
DEFAULT_THRESHOLD = 48
MAX_DIGITS = 256            # 4096 bits; bound analysis above covers <= 256

# Leaf cols + stacked operands + recombination temps, in (TB, m)-array
# equivalents (P*nb ~ (3/2)^depth * m, cols twice that, plus slices).
LIVE_U32_ARRAYS = 24
MAX_TILE = 128


def _ones_value(length: int) -> int:
    """1 + B + ... + B^(length-1) as a Python int."""
    return ((1 << (DBITS * length)) - 1) // (BASE - 1)


def _leaf_bound(width: int) -> int:
    """Max lazy column digit of a VnC leaf product: <= width lo terms
    (< B) plus width hi terms (< B) per column."""
    return 2 * width * (BASE - 1)


def _norm_sum(x, y):
    """(TB, k) + (TB, k) normalized digits -> (TB, k+1) normalized digits
    of the exact sum (digits of x + y are < 2**17: one static pass + the
    Kogge-Stone tail resolve exactly)."""
    s = x + y
    s = jnp.concatenate([s, jnp.zeros_like(s[:, :1])], axis=1)
    return normalize_static(s, DBITS, bound=1 << (DBITS + 1))


def _collect(x, y, threshold, leaves):
    """Trace-time recursion, phase A: gather every leaf operand pair.

    x, y: (TB, n) NORMALIZED digit arrays.  Returns a static spec tree;
    appends (x_leaf, y_leaf, width) to ``leaves``.  Odd widths above the
    threshold are zero-padded to even (value unchanged; the spec records
    the effective width).
    """
    n = x.shape[1]
    if n > threshold and n % 2:
        z = jnp.zeros_like(x[:, :1])
        x = jnp.concatenate([x, z], axis=1)
        y = jnp.concatenate([y, z], axis=1)
        n += 1
    if n <= threshold:
        idx = len(leaves)
        leaves.append((x, y, n))
        return ("leaf", n, idx)
    k = n // 2
    s0 = _collect(x[:, :k], y[:, :k], threshold, leaves)
    s1 = _collect(x[:, k:], y[:, k:], threshold, leaves)
    sa = _norm_sum(x[:, :k], x[:, k:])
    sb = _norm_sum(y[:, :k], y[:, k:])
    ss = _collect(sa, sb, threshold, leaves)
    return ("split", n, k, s0, s1, ss)


# Phase B (all base multiplies at once, (TB, P, nb) x2 -> (TB, P, 2nb)
# lazy cols): two schedules of the same math, picked per backend -- the
# row loop is the VPU-native form for TPU, the skew contraction avoids
# the serial update chain that dominates in CPU interpret mode.
_BASE_MODES = {"rows": vnc_cols_rows, "skew": vnc_cols_skew}


def _slice_add(dst, start: int, src):
    """dst[:, start:start+w] += src, as a plain add when the slice covers
    the whole axis (a full-axis .at[].add lowers to a scatter with an
    empty index constant, which pallas kernels cannot capture)."""
    w = src.shape[1]
    if start == 0 and w == dst.shape[1]:
        return dst + src
    return dst.at[:, start:start + w].add(src)


def _combine(spec, cols):
    """Trace-time recursion, phase C: lazy recombination.

    Returns (lazy_cols (TB, L), bound, const) with
    value(lazy_cols) == true_product + const, const a static Python int.
    """
    if spec[0] == "leaf":
        _, w, idx = spec
        return cols[:, idx, :2 * w], _leaf_bound(w), 0

    _, n, k, s0, s1, ss = spec
    c0, b0, k0c = _combine(s0, cols)
    c1, b1, k1c = _combine(s1, cols)
    cs, bs, ksc = _combine(ss, cols)
    l0, l1, ls = c0.shape[1], c1.shape[1], cs.shape[1]

    # middle = cs - c0 - c1 via per-digit complements (trick 1): the
    # static offsets K0*S(l0), K1*S(l1) join the node constant.
    lm = max(ls, l0, l1)
    tb = c0.shape[0]
    mid = jnp.zeros((tb, lm), U32)
    mid = _slice_add(mid, 0, cs)
    mid = _slice_add(mid, 0, np.uint32(b0) - c0)
    mid = _slice_add(mid, 0, np.uint32(b1) - c1)
    b_mid = bs + b0 + b1
    const_mid = ksc - k0c - k1c + b0 * _ones_value(l0) + b1 * _ones_value(l1)

    lout = max(2 * n, k + lm, 2 * k + l1)
    out = jnp.zeros((tb, lout), U32)
    out = _slice_add(out, 0, c0)
    out = _slice_add(out, k, mid)
    out = _slice_add(out, 2 * k, c1)
    # frames may overlap by a few pad digits; bound conservatively.
    bound = b_mid + b0 + b1
    assert bound + BASE < 1 << 31, \
        "lazy columns would overflow uint32 (width/threshold too large)"
    const = k0c + (const_mid << (DBITS * k)) + (k1c << (DBITS * 2 * k))
    return out, bound, const


def make_kara_kernel(m: int, threshold: int = DEFAULT_THRESHOLD,
                     base_mode: str = "rows"):
    """Kernel body for (TB, m) x (TB, m) -> (TB, 2m) normalized digits."""
    assert m <= MAX_DIGITS, "bound analysis covers <= 256 digits (4096 bits)"
    base_cols = _BASE_MODES[base_mode]

    def kara_kernel(a_ref, b_ref, out_ref):
        a = a_ref[...]                       # (TB, m) digits < 2**16
        b = b_ref[...]
        tb = a.shape[0]

        leaves = []                          # phase A: operand gathering
        spec = _collect(a, b, threshold, leaves)
        nb = max(w for _, _, w in leaves)
        apad = jnp.stack(
            [jnp.pad(x, ((0, 0), (0, nb - w))) for x, _, w in leaves], axis=1)
        bpad = jnp.stack(
            [jnp.pad(y, ((0, 0), (0, nb - w))) for _, y, w in leaves], axis=1)

        cols = base_cols(apad, bpad)         # phase B: all base multiplies

        out, bound, const = _combine(spec, cols)   # phase C: lazy recombine
        assert bound + BASE < 1 << 31, "lazy columns would overflow uint32"

        if const == 0:                       # pure base case (m <= threshold)
            final = out
            fbound = bound
        else:
            # cancel CONST: add digits of B^Lp - CONST, then the known
            # B^Lp marker carries out beyond the digits we read back.
            lout = out.shape[1]
            cap = bound * _ones_value(lout)          # max value(out)
            lp = max(lout, -(-cap.bit_length() // DBITS) + 1)
            d = (1 << (DBITS * lp)) - const
            assert 0 < d, "CONST exceeds the correction headroom"
            final = jnp.zeros((tb, lp + 1), U32)
            final = _slice_add(final, 0, out)
            # per-digit scalar adds (pallas kernels cannot capture
            # non-scalar constants); zero digits are skipped at trace time
            for i in range(lp):
                di = (d >> (DBITS * i)) & (BASE - 1)
                if di:
                    final = final.at[:, i].add(np.uint32(di))
            fbound = bound + BASE
        norm = normalize_static(final, DBITS, bound=fbound)
        out_ref[...] = norm[:, :2 * m]

    return kara_kernel


@functools.lru_cache(maxsize=32)
def make_call(batch_tile: int, m: int, grid: int, threshold: int,
              base_mode: str, interpret: bool):
    return pl.pallas_call(
        make_kara_kernel(m, threshold, base_mode),
        grid=(grid,),
        in_specs=[pl.BlockSpec((batch_tile, m), lambda i: (i, 0)),
                  pl.BlockSpec((batch_tile, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((batch_tile, 2 * m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * batch_tile, 2 * m), U32),
        interpret=interpret,
    )
