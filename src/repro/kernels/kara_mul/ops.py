"""Jit'd wrappers for the fused Karatsuba-over-VnC kernel.

Same conventions as the other kernel wrappers: interpret mode auto-
selected on CPU, batch padded to the tile and trimmed, tile chosen
outside jit by the common heuristic/autotuner.  The 32-bit limb entry
point pays the radix conversion at entry/exit (paper sec 3.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import autotune, tiling
from repro.kernels.common.runtime import auto_interpret as _auto_interpret
from repro.kernels.kara_mul import kernel as K
from repro.resilience import inject as _inject

U32 = jnp.uint32


def _heuristic_tile(m: int, batch: int) -> int:
    return tiling.batch_tile(
        m, batch, budget=tiling.budget_words(K.LIVE_U32_ARRAYS),
        max_tile=K.MAX_TILE)


@functools.partial(jax.jit, static_argnames=("tb", "threshold", "base_mode",
                                             "interpret"))
def _call(a, b, tb: int, threshold: int, base_mode: str, interpret: bool):
    batch, m = a.shape
    pad = (-batch) % tb
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = a.shape[0] // tb
    p = K.make_call(tb, m, grid, threshold, base_mode, interpret)(a, b)
    return p[:batch]


def kara_mul_digits(a_digits, b_digits, interpret=None,
                    threshold: int = K.DEFAULT_THRESHOLD,
                    base_mode: str | None = None):
    """(batch, m) uint32 radix-2**16 digits -> (batch, 2m) digits.

    m <= 256 (4096 bits); the whole Karatsuba tree runs in one launch.
    base_mode picks the phase-B schedule (common/vnc.py): the fused row
    loop ("rows", default -- measured fastest on CPU interpret too) or
    the skew contraction ("skew", kept selectable for autotune sweeps).
    """
    a = jnp.asarray(a_digits, U32)
    b = jnp.asarray(b_digits, U32)
    interpret = _auto_interpret(interpret)
    if base_mode is None:
        base_mode = "rows"
    batch, m = a.shape
    tb = autotune.pick_tile(
        "kara_mul", (m, batch, 16, threshold, base_mode, interpret),
        _heuristic_tile(m, batch), batch,
        run=lambda t: _call(a, b, t, threshold, base_mode, interpret),
        max_tile=K.MAX_TILE)
    return _call(a, b, tb, threshold, base_mode, interpret)


def kara_mul_limbs32(a_limbs, b_limbs, interpret=None,
                     threshold: int = K.DEFAULT_THRESHOLD):
    """(batch, m) uint32 saturated limbs -> (batch, 2m) limbs (full
    product), radix-converted at entry/exit."""
    _inject.fire("kernels/kara_mul")
    from repro.core import mul as coremul
    m = a_limbs.shape[-1]
    a_d = coremul.split_digits(jnp.asarray(a_limbs, U32), 16)
    b_d = coremul.split_digits(jnp.asarray(b_limbs, U32), 16)
    p_d = kara_mul_digits(a_d, b_d, interpret, threshold)
    return coremul.join_digits(p_d, 16, 2 * m)
