"""Observability inspector: replay a workload with tracing on and
print what the stack actually did.

Three sections, all driven through the ``repro.obs`` layer rather than
ad-hoc prints:

1. **Dispatch report** -- a sweep over the size/batch grid calls the
   real tier choosers (multiply / divide / modexp / window picker) so
   the report shows every dispatch tier and WHICH threshold picks it,
   straight from the dispatch-trace ring buffer.  The sweep only runs
   the Python dispatchers -- no device work -- so it covers the
   8192-bit NTT tier without compiling an 8192-bit multiply.
2. **Serving replay** -- a mixed RSA + mod_exp Poisson trace through
   the continuous-batching engine (same builder as launch/
   serve_bignum); per-bucket p50/p95/p99 come from the engine's OWN
   latency histograms, and the retrace counter proves the zero-retrace
   contract held.
3. **Artifacts** -- the span buffer as Chrome-trace JSON
   (``--trace-out``, load in chrome://tracing or ui.perfetto.dev) and
   optionally the full metrics snapshot (``--metrics-out``).

Usage:
  PYTHONPATH=src python -m repro.launch.inspect_bignum \
      --bits 256 --requests 24 --trace-out bignum_trace.json
"""
from __future__ import annotations

import argparse
import json

from repro import api, obs
from repro.configs.dot_bignum import (
    DIV_DISPATCH, MODEXP_DISPATCH, MUL_DISPATCH, SERVE, ServeConfig,
    pick_modexp_window)
from repro.core.div import select_div_method
from repro.core.modular import select_modexp_backend
from repro.core.mul import select_method
from repro.launch.serve_bignum import build_ops
from repro.serve.bignum_engine import BignumEngine, poisson_trace, \
    replay_trace


def dispatch_sweep() -> None:
    """Exercise every dispatch tier through the real choosers (pure
    host-side: no kernels launch, nothing compiles)."""
    mc, dc, xc = MUL_DISPATCH, DIV_DISPATCH, MODEXP_DISPATCH
    kb = mc.kernel_min_batch
    # multiply: every tier of select_method, batch-aware rules included
    for nbits in (mc.jnp_max_bits, mc.vnc_max_bits, mc.fused_kara_max_bits,
                  mc.ntt_min_bits - 32, mc.ntt_min_bits):
        select_method(nbits, batch=kb)
    select_method(mc.mxu_max_bits, batch=kb, prefer_mxu=True)
    select_method(mc.small_batch_dot_max_bits, batch=1)        # tiny batch
    select_method(mc.small_batch_dot_max_bits + 32, batch=1)   # batch-1 NTT
    # division: both backends, both batch regimes
    select_div_method(dc.schoolbook_max_bits, dc.schoolbook_max_bits,
                      batch=kb)
    select_div_method(2 * dc.schoolbook_max_bits, dc.schoolbook_max_bits,
                      batch=kb)
    select_div_method(dc.schoolbook_max_bits, dc.schoolbook_max_bits,
                      batch=1)
    # modexp: composition vs fused ladder, odd (Montgomery) and even
    # (Barrett) moduli -- mod_setup on an even modulus yields the
    # BarrettCtx that routes the barrett tiers
    eb = xc.fused_min_exp_bits
    select_modexp_backend(256, batch=xc.packed_min_batch, ebits=eb)
    select_modexp_backend(256, batch=1, ebits=eb)
    bctx = api.mod_setup((1 << 254) + 2, 256)                  # even: Barrett
    select_modexp_backend(256, batch=xc.packed_min_batch, ebits=eb,
                          ctx=bctx)
    select_modexp_backend(256, batch=1, ebits=eb, ctx=bctx)
    # window picker: short (RSA e=65537) vs long exponents
    pick_modexp_window(17)
    pick_modexp_window(2048)


def latency_table() -> list:
    """Per-bucket latency lines from the engine's own histograms."""
    hist = obs.REGISTRY.get("serve_request_latency_seconds")
    lines = []
    if hist is None:
        return lines
    for labels, row in hist.snapshot().items():
        pcts = " ".join(
            f"{k} {row[k] * 1e3:.2f}ms" for k in ("p50", "p95", "p99"))
        lines.append(f"  {labels}: n={row['count']} {pcts}")
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=256)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--slots", type=int, default=SERVE.slots)
    ap.add_argument("--backend", default="jnp",
                    help="modexp backend for the replay (jnp: fastest "
                         "compile on CPU interpret grids)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="bignum_trace.json",
                    help="Chrome-trace JSON output path")
    ap.add_argument("--metrics-out", default=None,
                    help="also dump the api.metrics() snapshot as JSON")
    args = ap.parse_args(argv)

    with api.configure(observability=True):
        obs.reset()
        dispatch_sweep()

        templates, warm = build_ops("mixed", args.bits, args.groups,
                                    args.seed)
        trace = poisson_trace(templates, args.requests, args.rate,
                              seed=args.seed)
        engine = BignumEngine(ServeConfig(slots=args.slots),
                              backend=args.backend)
        with obs.span("serve/warm", cat="trace", buckets=len(warm)):
            for w in warm:
                engine.warm(**w)
        res = replay_trace(engine, trace)

        print("== dispatch report (which tier, which threshold) ==")
        for line in obs.format_report():
            print(line)

        print("\n== serving replay (mixed rsa + mod_exp) ==")
        st = engine.stats
        print(f"  {res.n} reqs in {res.makespan_s:.3f}s = "
              f"{res.ops_per_s:.1f} ops/s | {st.batches} batches "
              f"({st.flush_full} full / {st.flush_deadline} deadline), "
              f"{st.padded_lanes} padded lanes, {st.programs} programs")
        print(f"  retraces after warm: "
              f"{obs.retrace.count('serve')} (contract: 0)")
        print("  per-bucket latency (engine histograms):")
        for line in latency_table():
            print(line)

        snap = api.metrics()
        print("\n== resilience ==")
        ctrs = snap["counters"]
        for name in ("fallback_total", "shed_total",
                     "deadline_miss_total", "selfcheck_failures_total"):
            series = ctrs.get(name, {})
            if not series:
                print(f"  {name}: (none)")
                continue
            for labels, v in series.items():
                print(f"  {name}{{{labels}}}: {int(v)}")
        brk = snap.get("breaker", {})
        for key, st_ in brk.get("keys", {}).items():
            extra = (f" (retry in {st_['retry_in_s']:.1f}s)"
                     if st_.get("retry_in_s") else "")
            print(f"  breaker {key}: {st_['state']}{extra}")
        for f in brk.get("forced", []):
            print(f"  breaker forced open: {f}")
        if not brk.get("keys") and not brk.get("forced"):
            print("  breaker: all closed")

        caches = snap["caches"]
        print("\n== caches ==")
        for name in ("twiddle", "operand", "autotune"):
            c = caches[name]
            print(f"  {name}: hits={c['hits']} misses={c['misses']} "
                  f"entries={c['entries']}")
        for name, c in caches["ctx"].items():
            print(f"  ctx/{name}: hits={c['hits']} misses={c['misses']} "
                  f"entries={c['entries']}")

        path = obs.write_chrome_trace(args.trace_out)
        nspans = len(obs.spans.spans())
        print(f"\nwrote {nspans} spans -> {path} "
              f"(chrome://tracing / ui.perfetto.dev)")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(snap, f, indent=1, default=str)
            print(f"wrote metrics snapshot -> {args.metrics_out}")
    return res


if __name__ == "__main__":
    main()
