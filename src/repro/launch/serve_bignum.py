"""Crypto-serving entrypoint: Poisson request trace through the
continuous-batching BignumEngine, with the one-at-a-time NaiveServer
replayed on the same trace for comparison.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_bignum \
      --bits 256 --requests 32 --rate 200 --slots 8 --op mixed
"""
from __future__ import annotations

import argparse
import copy
import random

from repro import api
from repro.configs.dot_bignum import SERVE, ServeConfig
from repro.serve.bignum_engine import (
    OPS, BignumEngine, NaiveServer, poisson_trace, replay_naive,
    replay_trace)


def build_ops(op: str, bits: int, groups: int, seed: int):
    """Request templates (dicts of BignumRequest kwargs) plus the warm
    list: ``groups`` distinct moduli/keys so the trace mixes shapes."""
    py = random.Random(seed)
    templates, warm = [], []
    if op in ("mod_exp", "mixed"):
        for g in range(groups):
            # distinct natural widths (bits, bits-16, ...) -> one bucket
            nb = bits - 16 * g
            n = py.getrandbits(nb) | 1 | (1 << (nb - 1))
            e = py.getrandbits(max(17, nb // 4)) | 1
            warm.append(dict(op="mod_exp", modulus=n, exponent=e))
            templates.append(dict(
                op="mod_exp", modulus=n, exponent=e,
                value=api.to_limbs(py.randrange(2, n), nb)))
    if op in ("rsa", "mixed"):
        key = api.generate_key(bits, seed=seed)
        msg = api.digest_int(b"serve_bignum", bits)
        for kind in ("rsa_sign", "rsa_verify", "rsa_decrypt"):
            warm.append(dict(op=kind, key=key))
            templates.append(dict(op=kind, key=key,
                                  value=api.to_limbs(msg, bits)))
    return templates, warm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=256)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (requests/s, virtual clock)")
    ap.add_argument("--slots", type=int, default=SERVE.slots)
    ap.add_argument("--max-wait", type=float, default=SERVE.max_wait_s)
    ap.add_argument("--groups", type=int, default=2,
                    help="distinct moduli in the mod_exp mix")
    ap.add_argument("--op", default="mixed",
                    choices=("mixed", "rsa") + OPS)
    ap.add_argument("--backend", default=None,
                    help="modexp backend override (e.g. jnp)")
    ap.add_argument("--naive", action="store_true",
                    help="also replay the one-at-a-time baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    templates, warm = build_ops(args.op, args.bits, args.groups, args.seed)
    trace = poisson_trace(templates, args.requests, args.rate,
                          seed=args.seed)

    cfg = ServeConfig(slots=args.slots, max_wait_s=args.max_wait)
    engine = BignumEngine(cfg, backend=args.backend)
    for w in warm:
        engine.warm(**w)
    warm_traces = engine.stats.traces

    res = replay_trace(engine, trace)
    st = engine.stats
    print(f"[serve_bignum] engine: {res.n} reqs in {res.makespan_s:.3f}s "
          f"= {res.ops_per_s:.1f} ops/s | p50 {res.p50_ms:.2f}ms "
          f"p99 {res.p99_ms:.2f}ms")
    print(f"[serve_bignum] engine: {st.batches} batches "
          f"({st.flush_full} full / {st.flush_deadline} deadline), "
          f"{st.padded_lanes} padded lanes, {st.programs} programs, "
          f"{st.traces - warm_traces} retraces after warm")

    if args.naive:
        naive = NaiveServer(backend=args.backend)
        nres = replay_naive(naive, copy.deepcopy(trace))
        print(f"[serve_bignum] naive:  {nres.n} reqs in "
              f"{nres.makespan_s:.3f}s = {nres.ops_per_s:.1f} ops/s | "
              f"p50 {nres.p50_ms:.2f}ms p99 {nres.p99_ms:.2f}ms "
              f"({naive.stats.traces} compiles in-trace)")
        print(f"[serve_bignum] engine vs naive throughput: "
              f"{res.ops_per_s / nres.ops_per_s:.2f}x")
    return res


if __name__ == "__main__":
    main()
