"""Production training entrypoint.

Wires together: mesh + sharding rules (FSDP/TP/DP) -> model -> trainer
(microbatch accumulation, exact deferred-carry gradient reduction) ->
checkpointing (atomic, signed, async) -> fault tolerance (resume from the
newest valid checkpoint, straggler monitoring).

On this CPU container it drives reduced configs end-to-end (see
examples/train_smollm.py); on a real pod the same file runs the full
configs -- device count and mesh shape are the only changes.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
      --reduced --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as sh
from repro.models import build_model
from repro.train import checkpoint as CKPT
from repro.train import fault_tolerance as FT
from repro.train import optimizer as OPT
from repro.train import trainer as TR


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-reduce", default="mean",
                    choices=["mean", "exact"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="0: use all devices for data parallelism")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.replace(remat="none")
    model = build_model(cfg)

    n_dev = len(jax.devices())
    data_ax = args.data_axis or max(1, n_dev // args.model_axis)
    mesh = jax.make_mesh((data_ax, args.model_axis), ("data", "model"))
    multi_device = n_dev > 1
    if multi_device:
        sh.enable_fsdp(mesh)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0))

    tcfg = TR.TrainerConfig(
        opt=OPT.OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                          total_steps=args.steps),
        microbatches=args.microbatches,
        grad_reduce=args.grad_reduce)

    params = model.init(jax.random.key(0))
    opt_state = OPT.init(params)
    start_step = 0

    monitor = FT.StragglerMonitor()
    saver = None
    if args.ckpt_dir:
        rm = FT.RestartManager(args.ckpt_dir)
        step0, state = rm.resume({"params": params, "opt": opt_state})
        if step0 is not None:
            params, opt_state = state["params"], state["opt"]
            start_step = step0 + 1
            print(f"[train] resumed from step {step0}")
        saver = CKPT.AsyncSaver(args.ckpt_dir)

    step_fn = TR.make_train_step(model, tcfg)
    if multi_device:
        pspecs = sh.param_pspecs(jax.eval_shape(lambda: params), mesh)
        p_shard = sh.to_shardings(pspecs, mesh)
        o_shard = sh.to_shardings(
            {"m": pspecs, "v": pspecs, "step": jax.sharding.PartitionSpec()},
            mesh)
        step_fn = jax.jit(step_fn, in_shardings=(p_shard, o_shard, None),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))
        params = jax.device_put(params, p_shard)
        opt_state = jax.device_put(opt_state, o_shard)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    t_start = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            monitor.start()
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            ev = monitor.stop(step)
            if ev:
                print(f"[straggler] step {ev.step}: {ev.ratio:.1f}x median "
                      f"-> {ev.action}")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step}: loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
            if saver and (step % args.ckpt_every == 0 or step == args.steps - 1):
                saver.save(step, {"params": params, "opt": opt_state})
    if saver:
        saver.wait()
    dt = time.time() - t_start
    tokens = (args.steps - start_step) * args.batch * args.seq
    print(f"[train] done: {dt:.1f}s, {tokens / dt:.0f} tokens/s")
    return params


if __name__ == "__main__":
    main()
