"""Production mesh construction.

Importing this module never touches JAX device state; meshes are built
lazily inside functions (so smoke tests see 1 device while the dry-run,
which sets XLA_FLAGS before any import, sees 512).

Production target: TPU v5e pods, 256 chips each (16x16 mesh per pod);
the multi-pod configuration adds a leading "pod" axis over DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist;
    used by subprocess-based distribution tests."""
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW_PER_LINK = 50e9       # bytes/s/link (~6 links usable per chip on a
                             # 2D torus slice; roofline uses chips x link_bw
                             # per the assignment's formula)
