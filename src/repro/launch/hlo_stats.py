"""Parse collective-communication bytes out of compiled HLO text.

``compiled.cost_analysis()`` does not expose collective traffic, so the
roofline's collective term comes from summing the result-shape bytes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute in the post-SPMD module (async -start forms counted
once; -done forms skipped).  Ops inside while-loop (scan) bodies appear
once in the text; launch/roofline.py re-multiplies them via the
segment-delta correction.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+([a-z0-9-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result bytes per collective opcode.  Returns {opcode: bytes,
    'total': bytes}."""
    out = defaultdict(float)
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, opcode = m.group(1), m.group(2)
        if opcode.endswith("-done"):
            continue
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base not in COLLECTIVES:
            continue
        out[base] += _shape_bytes(shape_str)
    out["total"] = sum(v for k, v in out.items())
    return dict(out)
