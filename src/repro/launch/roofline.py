"""Roofline analysis from the dry-run's compiled artifacts.

Three terms per (arch x shape), single-pod mesh (256 chips):

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_chip / link_bw      (50 GB/s/link)

``cost_analysis()`` and the HLO collective parse are per-chip post-SPMD
numbers, but count every ``lax.scan`` (while-loop) body ONCE.  The
dry-run therefore compiles each cell twice -- default segmentation and
one extra scan over the same layers -- and the cost delta isolates one
scan-body's contribution:

  true = C(base) + (num_layers - num_scans_base) * (C(split) - C(base))

Also reported: MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (serve),
and the usefulness ratio MODEL_FLOPS / HLO_FLOPS (remat/redundancy waste
shows up here: full remat targets ~0.75, i.e., 4/3 recompute overhead).
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

CHIPS_SINGLE_POD = 256


def _load(out_dir: pathlib.Path, arch, shape, mesh, variant) -> Optional[dict]:
    p = out_dir / f"{arch}.{shape}.{mesh}.{variant}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("ok") else None


def corrected_cell(out_dir: pathlib.Path, arch: str, shape: str,
                   mesh: str = "single") -> Optional[dict]:
    """Scan-corrected per-chip flops / bytes / collective bytes + terms."""
    base = _load(out_dir, arch, shape, mesh, "base")
    if base is None:
        return None
    flops = base["cost"]["flops"]
    bytes_ = base["cost"]["bytes_accessed"]
    coll = base["collectives"].get("total", 0.0)

    scan_info = base["scan_info"]
    variants = (["split"] if len(scan_info) == 1
                else ["split_enc", "split_dec"])
    names = list(scan_info)
    for vname, sname in zip(variants, names):
        split = _load(out_dir, arch, shape, mesh, vname)
        units, segments = scan_info[sname]
        n_scans = len(segments)
        extra = units - n_scans
        if split is None or extra <= 0:
            continue
        d_f = max(0.0, split["cost"]["flops"] - flops)
        d_b = max(0.0, split["cost"]["bytes_accessed"] - bytes_)
        d_c = max(0.0, split["collectives"].get("total", 0.0) - coll)
        flops += extra * d_f
        bytes_ += extra * d_b
        coll += extra * d_c

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW_PER_LINK
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_total = flops * CHIPS_SINGLE_POD
    model = base["model_flops"]
    mem = base["memory"]
    return {
        "arch": arch, "shape": shape,
        "flops_per_chip": flops, "bytes_per_chip": bytes_,
        "coll_bytes_per_chip": coll,
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant,
        "model_flops": model,
        "useful_ratio": model / hlo_total if hlo_total else 0.0,
        "roofline_fraction": t_compute / max(max(terms.values()), 1e-30),
        "hbm_args_gb": mem["argument_bytes"] / 2 ** 30,
        "hbm_temp_gb": mem["temp_bytes"] / 2 ** 30,
        "params": base["params"],
        "active_params": base["active_params"],
    }


def suggestion(cell: dict) -> str:
    d = cell["dominant"]
    if d == "collective":
        return ("cut collective bytes: bf16/int8 weight gathers, "
                "reduce-scatter grads, larger per-step compute per gather")
    if d == "memory":
        return ("raise arithmetic intensity: fuse attention (flash), "
                "bf16 caches, larger batch per chip")
    if cell["useful_ratio"] < 0.6:
        return ("compute-bound but wasteful: reduce remat recompute / "
                "causal-mask dead FLOPs / padded heads")
    return "compute-bound near roofline: tune block shapes / overlap tails"


def table(out_dir, mesh="single") -> str:
    out_dir = pathlib.Path(out_dir)
    cells = []
    seen = set()
    for p in sorted(out_dir.glob(f"*.{mesh}.base.json")):
        arch, shape = p.name.split(".")[:2]
        if (arch, shape) in seen:
            continue
        seen.add((arch, shape))
        c = corrected_cell(out_dir, arch, shape, mesh)
        if c:
            cells.append(c)

    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac | HBM args GB | HBM temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute']:.3e} | "
            f"{c['t_memory']:.3e} | {c['t_collective']:.3e} | "
            f"**{c['dominant']}** | {c['useful_ratio']:.2f} | "
            f"{c['roofline_fraction']:.2f} | {c['hbm_args_gb']:.2f} | "
            f"{c['hbm_temp_gb']:.1f} |")
    return hdr + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        out_dir = pathlib.Path(args.dir)
        cells = {}
        for p in sorted(out_dir.glob(f"*.{args.mesh}.base.json")):
            arch, shape = p.name.split(".")[:2]
            c = corrected_cell(out_dir, arch, shape, args.mesh)
            if c:
                cells[f"{arch}.{shape}"] = c
        print(json.dumps(cells, indent=1))
    else:
        print(table(args.dir, args.mesh))


if __name__ == "__main__":
    main()
