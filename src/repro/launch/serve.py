"""Serving entrypoint: batched greedy decoding through the ServeEngine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --reduced \
      --requests 6 --prompt-len 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, EngineConfig(
        slots=args.slots, max_seq=args.max_seq))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (args.prompt_len,)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"[serve] req {rid}: {out[rid]}")
    print(f"[serve] {total} tokens in {dt:.2f}s = {total / dt:.1f} tok/s "
          f"({args.requests} requests, {args.slots} slots)")
    return out


if __name__ == "__main__":
    main()
