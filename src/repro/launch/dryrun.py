import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization.
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step with AdamW
update for train shapes; prefill / serve_step for inference shapes) with
production shardings, compiles it, and records:
  * compiled.memory_analysis()  -- proves the cell fits per-device HBM
  * compiled.cost_analysis()    -- HLO FLOPs / bytes for the roofline
  * collective bytes parsed from the post-SPMD HLO text
  * analytic MODEL_FLOPS (6*N*D train / 2*N_active*D serve)

Variants: "base" uses the default layer-scan segmentation; "split" adds
one extra scan over the same layers so roofline.py can isolate the
scan-body cost (cost_analysis counts loop bodies once).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_8b \
      --shape train_4k --mesh single --variant base --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all  # full sweep, resumable
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as sh
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES_BY_NAME, applicable_shapes, build_model
from repro.train import optimizer


# ---------------------------------------------------------------------------
# analytic FLOPs (roofline denominator sanity): 6*N*D (dense train),
# 6*N_active*D (MoE train), 2*N_active per generated token (serve).
# ---------------------------------------------------------------------------

def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def active_params(cfg, params_shapes) -> int:
    total = count_params(params_shapes)
    if cfg.num_experts == 0:
        return total
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        p = "/".join(str(getattr(k, "key", k)) for k in path)
        if "moe/w" in p:
            expert += int(np.prod(leaf.shape))
    return total - expert + expert * cfg.top_k // cfg.num_experts


def model_flops(cfg, shape, params_shapes) -> float:
    n_act = active_params(cfg, params_shapes)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch      # decode: one token / seq


# ---------------------------------------------------------------------------
# segment variants for the scan-body cost extraction
# ---------------------------------------------------------------------------

def segment_variants(cfg):
    """Returns {variant_name: segments_arg}, where segments_arg feeds
    build_model(cfg, segments=...)."""
    model = build_model(cfg)
    info = model.scan_info()
    out = {"base": None}

    def split_first(segs):
        segs = list(segs)
        for i, s in enumerate(segs):
            if s >= 2:
                return tuple(segs[:i] + [s - 1, 1] + segs[i + 1:])
        return tuple(segs)

    if cfg.family == "audio":
        enc_u, enc_segs = info["enc"]
        dec_u, dec_segs = info["dec"]
        out["split_enc"] = {"enc": split_first(enc_segs), "dec": dec_segs}
        out["split_dec"] = {"enc": enc_segs, "dec": split_first(dec_segs)}
    else:
        units, segs = info["layers"]
        out["split"] = split_first(segs)
    return out


# ---------------------------------------------------------------------------
# cell construction + compile
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh_kind: str, variant: str):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape.kind in ("prefill", "decode"):
        # serving runs on bf16 weights: halves weight reads + FSDP gather
        # traffic in the memory-bound decode regime (SSPerf cell 3, iter 1)
        cfg = cfg.replace(param_dtype="bfloat16")
    # decode with kv_heads < TP: row-parallel attention + seq-sharded cache
    sh.set_attn_row_parallel(
        shape.kind == "decode" and cfg.num_kv_heads > 0
        and cfg.num_kv_heads % 16 != 0)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    segments = segment_variants(cfg)[variant]
    model = build_model(cfg, segments=segments)

    params_s = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = sh.param_pspecs(params_s, mesh)
    sh.enable_fsdp(mesh)
    p_shard = sh.to_shardings(pspecs, mesh)
    batch_s = model.input_specs(shape)
    b_shard = sh.to_shardings(sh.batch_pspecs(batch_s, mesh), mesh)

    with mesh:
        if shape.kind == "train":
            opt_s = jax.eval_shape(optimizer.init, params_s)
            o_pspec = {"m": pspecs, "v": pspecs,
                       "step": jax.sharding.PartitionSpec()}
            o_shard = sh.to_shardings(o_pspec, mesh)
            opt_cfg = optimizer.OptConfig()

            def train_step(params, opt, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
                params, opt, om = optimizer.update(opt_cfg, grads, opt, params)
                return params, opt, {"loss": loss, **metrics, **om}

            fn = jax.jit(train_step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_s, opt_s, batch_s)
        elif shape.kind == "prefill":
            cache_s = model.cache_specs(shape.global_batch, shape.seq_len)
            c_shard = sh.to_shardings(
                sh.cache_pspecs(cache_s, mesh, shape.global_batch,
                                shape.seq_len), mesh)

            def prefill_step(params, batch, cache):
                return model.prefill(params, batch, cache)

            fn = jax.jit(prefill_step,
                         in_shardings=(p_shard, b_shard, c_shard),
                         donate_argnums=(2,))
            lowered = fn.lower(params_s, batch_s, cache_s)
        else:  # decode
            cache_s = model.cache_specs(shape.global_batch, shape.seq_len)
            c_shard = sh.to_shardings(
                sh.cache_pspecs(cache_s, mesh, shape.global_batch,
                                shape.seq_len), mesh)
            tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            t_shard = sh.to_shardings(
                sh.batch_pspecs(tok_s, mesh), mesh)
            idx_s = jax.ShapeDtypeStruct((), jnp.int32)

            def serve_step(params, cache, tokens, index):
                return model.decode_step(params, cache, tokens, index)

            fn = jax.jit(serve_step,
                         in_shardings=(p_shard, c_shard, t_shard, None),
                         donate_argnums=(1,))
            lowered = fn.lower(params_s, cache_s, tok_s, idx_s)
    return cfg, shape, params_s, lowered


def run_cell(arch, shape_name, mesh_kind, variant, out_dir,
             keep_hlo: bool = False):
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "ok": False}
    t0 = time.time()
    try:
        cfg, shape, params_s, lowered = lower_cell(
            arch, shape_name, mesh_kind, variant)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        }
        cost = compat.cost_analysis_dict(compiled)
        rec["cost"] = {"flops": cost.get("flops", 0.0),
                       "bytes_accessed": cost.get("bytes accessed", 0.0)}
        txt = compiled.as_text()
        rec["collectives"] = hlo_stats.collective_bytes(txt)
        rec["hlo_lines"] = txt.count("\n")
        rec["params"] = count_params(params_s)
        rec["active_params"] = active_params(cfg, params_s)
        rec["model_flops"] = model_flops(cfg, shape, params_s)
        model = build_model(cfg)
        rec["scan_info"] = {k: [v[0], list(v[1])]
                            for k, v in model.scan_info().items()}
        rec["ok"] = True
        if keep_hlo:
            (out_dir / f"{arch}.{shape_name}.{mesh_kind}.{variant}.hlo.txt"
             ).write_text(txt)
    except Exception as e:  # noqa: BLE001 - record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}.{shape_name}.{mesh_kind}.{variant}.json"
    path.write_text(json.dumps(rec, indent=1))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[dryrun] {arch} {shape_name} {mesh_kind} {variant}: {status} "
          f"({rec['total_s']}s)", flush=True)
    return rec


def enumerate_cells(mesh_kinds=("single", "multi"), variants_on="single"):
    """Full sweep: every (arch x applicable shape x mesh); segment-split
    variants only on the roofline (single-pod) mesh."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mesh_kind in mesh_kinds:
                cells.append((arch, shape.name, mesh_kind, "base"))
                if mesh_kind == variants_on:
                    for v in segment_variants(cfg):
                        if v != "base":
                            cells.append((arch, shape.name, mesh_kind, v))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.all:
        cells = enumerate_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.mesh, args.variant)]

    n_fail = 0
    for cell in cells:
        path = out_dir / ("%s.%s.%s.%s.json" % cell)
        if args.skip_existing and path.exists():
            rec = json.loads(path.read_text())
            if rec.get("ok"):
                continue
        rec = run_cell(*cell, out_dir=out_dir, keep_hlo=args.keep_hlo)
        n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
