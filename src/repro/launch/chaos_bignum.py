"""Chaos harness: deterministic fault injection against the serving
engine, with EXACT counter accounting as the pass/fail gates.

Installs ``repro.resilience.inject`` specs (compile failures on the
fused modexp ladder's guarded dispatch, flush-time errors, latency
spikes, result-limb corruption), warms a mixed mod_exp + RSA engine,
replays a Poisson trace, and then asserts the fault-tolerance contract:

  1. zero unhandled exceptions -- every injected failure was absorbed
     by guard fallback, flush retry, or bucket degradation;
  2. every served (non-shed) result is bit-exact against the python-int
     reference -- corrupted lanes were caught by the residue/witness
     self-check and repaired;
  3. zero retrace ALARMS -- ``on_retrace="raise"`` is armed, so the
     run itself proves no unexpected recompiles (degradation-forced
     recompiles are declared via the engine's expected-trace flag);
  4. ``fallback_total{reason="injected"}`` equals the number of
     realized compile_fail injections, one-to-one;
  5. ``selfcheck_failures_total`` equals the number of realized
     corrupt injections (each flips one bit of one real lane);
  6. every requested fault kind actually fired (non-vacuity).

Usage (CI smoke):
  PYTHONPATH=src python -m repro.launch.chaos_bignum --seed 0 \
      --inject compile_fail,latency,corrupt --smoke \
      --metrics-out chaos_metrics.json
"""
from __future__ import annotations

import argparse
import json
import sys
import warnings

import numpy as np

from repro import api
from repro.configs.dot_bignum import ServeConfig
from repro.launch.serve_bignum import build_ops
from repro.obs import metrics as _metrics
from repro.resilience import inject, selfcheck
from repro.resilience.breaker import BREAKER
from repro.resilience.guard import METRIC as FALLBACK
from repro.serve.bignum_engine import (
    BignumEngine, poisson_trace, replay_trace)


def install_specs(kinds, seed: int) -> None:
    """The injection plan.  Sites are chosen so every resilience layer
    absorbs at least one fault: ``compile_fail`` hits the guarded
    kernel dispatch at TRACE time (the fused modexp ladder tiers, so
    warm() sees it and the guard falls through pallas -> jnp ->
    reference inside the jit); ``flush_error`` hammers one bucket's
    flush until retries exhaust and the engine degrades it a backend
    tier; ``latency`` stalls flushes; ``corrupt`` flips result bits
    downstream of a correct kernel for the self-check to catch."""
    if "compile_fail" in kinds:
        inject.install("compile_fail", "modexp/", every=1, count=2)
    if "flush_error" in kinds:
        inject.install("flush_error", "serve/flush/rsa_verify",
                       every=1, count=3)
    if "latency" in kinds:
        inject.install("latency", "serve/flush", every=3, count=3,
                       delay_s=0.02)
    if "corrupt" in kinds:
        inject.install("corrupt", "serve/flush", every=5, seed=seed)


def run(args) -> int:
    kinds = [k for k in args.inject.split(",") if k]
    bad = set(kinds) - set(inject.KINDS)
    if bad:
        raise SystemExit(f"unknown inject kinds {sorted(bad)}; "
                         f"choose from {inject.KINDS}")
    n_requests = 40 if args.smoke else args.requests

    api.configure(observability=True, selfcheck="warn",
                  on_retrace="raise")
    _metrics.REGISTRY.reset()
    BREAKER.reset()
    inject.clear()
    install_specs(kinds, args.seed)

    templates, warm = build_ops("mixed", args.bits, args.groups,
                                args.seed)
    trace = poisson_trace(templates, n_requests, args.rate,
                          seed=args.seed)
    engine = BignumEngine(ServeConfig(), backend=None)
    failures = []
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", selfcheck.SelfCheckWarning)
            for w in warm:
                engine.warm(**w)
            res = replay_trace(engine, trace)
            engine.close()
    finally:
        plan = inject.log()
        inject.clear()
        BREAKER.reset()

    # gate 2: bit-exactness of every served result vs the host reference
    wrong = shed = 0
    for r in trace:
        if r.shed:
            shed += 1
            continue
        v = api.from_limbs(np.asarray(r.value, np.uint32).reshape(-1))
        expect = selfcheck.repair_lane(r.op, v, modulus=r.modulus,
                                       exponent=r.exponent, key=r.key)
        if api.from_limbs(np.asarray(r.result)) != expect:
            wrong += 1
    if wrong:
        failures.append(f"{wrong} served result(s) not bit-exact")

    # gates 3-5: counters vs the realized injection plan, exactly
    reg = _metrics.REGISTRY
    retraces = reg.counter("retraces_total").total()
    if retraces:
        failures.append(f"{int(retraces)} unexpected retrace(s)")
    injected = reg.counter(FALLBACK).total(reason="injected")
    n_compile = sum(1 for e in plan if e["kind"] == "compile_fail")
    if injected != n_compile:
        failures.append(
            f"fallback_total{{reason=injected}} = {int(injected)} but "
            f"{n_compile} compile_fail injection(s) realized")
    sc = reg.counter(selfcheck.METRIC).total()
    n_corrupt = sum(1 for e in plan if e["kind"] == "corrupt")
    if sc != n_corrupt:
        failures.append(
            f"selfcheck_failures_total = {int(sc)} but {n_corrupt} "
            f"corrupt injection(s) realized")

    # gate 6: every requested kind fired at least once
    realized = {e["kind"] for e in plan}
    for k in kinds:
        if k not in realized:
            failures.append(f"requested fault kind {k!r} never fired")

    st = engine.stats
    by_kind = ", ".join(
        "{}={}".format(k, sum(1 for e in plan if e["kind"] == k))
        for k in sorted(realized)) or "none"
    print(f"[chaos_bignum] {res.n} reqs ({shed} shed) in "
          f"{res.makespan_s:.3f}s | {len(plan)} injections realized "
          f"({by_kind})")
    print(f"[chaos_bignum] retries={st.retries} degraded={st.degraded} "
          f"selfcheck_failures={st.selfcheck_failures} "
          f"deadline_misses={st.deadline_misses} "
          f"fallback_injected={int(injected)} retrace_alarms="
          f"{int(retraces)}")

    if args.metrics_out:
        snap = api.metrics() or _metrics.REGISTRY.snapshot()
        payload = {"gates_failed": failures, "injections": plan,
                   "shed": shed, "metrics": snap}
        with open(args.metrics_out, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"[chaos_bignum] metrics -> {args.metrics_out}")

    if failures:
        for f in failures:
            print(f"[chaos_bignum] GATE FAILED: {f}", file=sys.stderr)
        return 1
    print("[chaos_bignum] all gates passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject", default=",".join(inject.KINDS),
                    help="comma list of fault kinds to install")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="40-request CI-sized run")
    ap.add_argument("--bits", type=int, default=256)
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    sys.exit(main())
