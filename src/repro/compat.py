"""Thin jax-version compatibility layer.

The repo targets the stable jax API surface; on older jaxlib (0.4.x, the
pinned toolchain here) two spellings differ:

  * ``jax.shard_map`` lives at ``jax.experimental.shard_map.shard_map``
    (keyword ``check_rep`` instead of ``check_vma``),
  * ``Compiled.cost_analysis()`` returns a one-element list of dicts
    instead of a dict.

Everything else routes through jax directly; keep this module tiny.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """jax.shard_map with the old experimental fallback."""
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               **kwargs)


def cost_analysis_dict(compiled) -> dict:
    """Compiled.cost_analysis() as a flat dict on every jax version."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
