"""Runtime dispatch overrides: the ONE home for the knobs that steer the
multiply / division / modexp dispatchers and the autotune sweep.

``repro.api.configure(...)`` writes here (process-wide, or scoped with
its context-manager form); the legacy ``REPRO_*`` environment variables
keep working as DEPRECATED aliases -- one DeprecationWarning per
variable per process -- at lower precedence than ``configure()``.

Precedence, highest first:

  1. ``repro.api.configure(...)`` values,
  2. the deprecated env vars (``REPRO_MUL_BACKEND`` /
     ``REPRO_DIV_BACKEND`` / ``REPRO_MODEXP_BACKEND`` /
     ``REPRO_AUTOTUNE``),
  3. the size/batch dispatch heuristics in ``configs/dot_bignum.py``
     (consulted by the ``select_*`` functions when ``resolve`` returns
     None).

This module is import-light on purpose (stdlib only): the core modules
consult it from inside their dispatch functions, and nothing here may
pull jax or the kernel packages into the import graph.
"""
from __future__ import annotations

import os
import warnings

OVERRIDE_NAMES = ("mul_method", "div_method", "modexp_backend", "autotune",
                  "ntt_cache_entries", "observability", "on_retrace",
                  "selfcheck", "kernel_fallback")

# ntt_cache_entries / observability / on_retrace / selfcheck /
# kernel_fallback have no env aliases: they never existed as REPRO_*
# vars, so there is no legacy spelling to keep working.
# ``observability`` is the repro.obs master switch (dispatch trace +
# spans + engine metric ticking); ``on_retrace`` picks the
# retrace-alarm policy ("ignore" / "warn" / "raise", see
# repro/obs/retrace.py -- the retrace COUNTER ticks regardless);
# ``selfcheck`` arms residue/witness result verification (None/False
# off, "warn" / "raise" policies, see repro/resilience/selfcheck.py);
# ``kernel_fallback`` gates degradation through the guarded kernel
# tiers (None/True degrade, False strict -- first failure propagates,
# see repro/resilience/guard.py).
ENV_ALIASES = {
    "mul_method": "REPRO_MUL_BACKEND",
    "div_method": "REPRO_DIV_BACKEND",
    "modexp_backend": "REPRO_MODEXP_BACKEND",
    "autotune": "REPRO_AUTOTUNE",
}

_overrides: dict = {name: None for name in OVERRIDE_NAMES}
_env_warned: set = set()


def get_override(name: str):
    """The configure() value for ``name`` (None: unset)."""
    return _overrides[name]


def set_overrides(updates: dict) -> dict:
    """Apply configure() values; returns the PREVIOUS values so the
    context-manager form can restore them.  A None value clears the
    override (dispatch falls back to env alias, then heuristics)."""
    prev = {}
    for name, value in updates.items():
        if name not in _overrides:
            raise TypeError(
                f"unknown configure() option {name!r}; choose from "
                f"{OVERRIDE_NAMES}")
        prev[name] = _overrides[name]
        _overrides[name] = value
    return prev


def _env_value(name: str):
    env_var = ENV_ALIASES.get(name)
    if env_var is None:
        return None
    raw = os.environ.get(env_var, "")
    if not raw:
        return None
    if env_var not in _env_warned:
        _env_warned.add(env_var)
        warnings.warn(
            f"{env_var} is deprecated; use repro.api.configure("
            f"{name}=...) (process-wide) or its context-manager form "
            f"(scoped) instead",
            DeprecationWarning, stacklevel=4)
    return raw


def resolve(name: str, valid=None, what: str = "value"):
    """The active override for ``name``: configure() first, then the
    deprecated env alias; None when neither is set (caller falls back
    to its heuristics).  ``valid`` checks membership and raises the
    repo-standard "unknown ...; choose from ..." error, naming the
    source so a stale env var is identifiable from the message."""
    value = _overrides[name]
    src = f"repro.api.configure({name}=...)"
    if value is None:
        value = _env_value(name)
        src = ENV_ALIASES.get(name, src)
    if value is None:
        return None
    if valid is not None and value not in valid:
        raise ValueError(
            f"unknown {what} {value!r} (via {src}); choose from {valid}")
    return value


def autotune_enabled() -> bool:
    """The autotune knob: configure(autotune=...) wins; the deprecated
    REPRO_AUTOTUNE env var parses as a boolean string; default off."""
    value = resolve("autotune")
    if value is None:
        return False
    if isinstance(value, bool):
        return value
    return str(value).lower() not in ("", "0", "false", "off")
