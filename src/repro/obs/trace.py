"""Dispatch tracing: structured events from the dispatch tier choosers.

``core/mul.select_method``, ``core/div.select_div_method``,
``core/modular.select_modexp_backend`` and ``configs/dot_bignum.
pick_modexp_window`` call ``emit(...)`` with the decision they just
made and WHICH threshold fired.  Events land in a bounded ring buffer
(and tick a ``dispatch_total`` counter in the metrics registry), so an
operator can ask "which backend did the 8192-bit batch-1 multiplies
actually take, and why" without the ``--show-dispatch`` print
statements this replaces.

Cost model: dispatch decisions happen at Python dispatch / jit-trace
time, never per element, and ``emit`` is a no-op unless observability
is on (``repro.api.configure(observability=True)``) -- the disabled
path is one dict lookup, no event object is ever allocated
(tests/test_obs.py asserts this via the buffer and counters).

Subscribers (``subscribe(fn)``) see each event as it is emitted --
the hook for streaming dispatch logs somewhere live.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional, Tuple

from repro import config as _config

DEFAULT_CAPACITY = 1024

DISPATCHERS = ("mul", "div", "modexp", "modexp_window")


@dataclasses.dataclass(frozen=True)
class DispatchEvent:
    """One dispatch decision.  ``rule`` names the threshold that fired
    (e.g. "nbits<=vnc_max_bits(512)"), ``detail`` carries dispatcher-
    specific extras as sorted (key, value) pairs."""

    dispatcher: str
    nbits: int
    batch: int
    choice: str
    rule: str
    detail: Tuple[Tuple[str, object], ...] = ()


_events: deque = deque(maxlen=DEFAULT_CAPACITY)
_subscribers: List[Callable[[DispatchEvent], None]] = []


def enabled() -> bool:
    """Observability master switch (configure(observability=True))."""
    return bool(_config.get_override("observability"))


def emit(dispatcher: str, nbits: int, batch: int, choice: str, rule: str,
         **detail) -> None:
    """Record one dispatch decision; no-op (and no allocation) when
    observability is off."""
    if not _config.get_override("observability"):
        return
    ev = DispatchEvent(dispatcher, int(nbits), int(batch), str(choice),
                       rule, tuple(sorted(detail.items())))
    _events.append(ev)
    from repro.obs import metrics as _m
    _m.REGISTRY.counter(
        "dispatch_total", "dispatch decisions by tier chooser").inc(
        dispatcher=dispatcher, choice=choice)
    for fn in list(_subscribers):
        fn(ev)


def subscribe(fn: Callable[[DispatchEvent], None]) -> Callable[[], None]:
    """Register a per-event callback; returns the unsubscriber."""
    _subscribers.append(fn)

    def unsubscribe():
        if fn in _subscribers:
            _subscribers.remove(fn)
    return unsubscribe


def events(dispatcher: Optional[str] = None) -> List[DispatchEvent]:
    """Buffered events, oldest first (optionally one dispatcher's)."""
    if dispatcher is None:
        return list(_events)
    return [e for e in _events if e.dispatcher == dispatcher]


def clear() -> None:
    _events.clear()


def set_capacity(n: int) -> None:
    """Resize the ring buffer (keeps the newest ``n`` events)."""
    global _events
    if n < 1:
        raise ValueError(f"trace capacity must be >= 1, got {n}")
    _events = deque(_events, maxlen=n)


def report(evts: Optional[List[DispatchEvent]] = None) -> List[dict]:
    """Aggregate events into {dispatcher, nbits, batch, choice, rule,
    detail, count} rows (insertion-ordered) -- the payload behind
    ``repro.api.dispatch_report()``."""
    rows: dict = {}
    for e in (_events if evts is None else evts):
        key = (e.dispatcher, e.nbits, e.batch, e.choice, e.rule, e.detail)
        rows[key] = rows.get(key, 0) + 1
    return [
        {"dispatcher": d, "nbits": nb, "batch": b, "choice": c,
         "rule": r, "detail": dict(det), "count": n}
        for (d, nb, b, c, r, det), n in rows.items()]


def format_report(rows: Optional[List[dict]] = None) -> List[str]:
    """Human-readable report lines, grouped by dispatcher (shared by
    ``--show-dispatch`` in the examples and the inspect CLI)."""
    rows = report() if rows is None else rows
    lines = []
    for disp in DISPATCHERS:
        mine = [r for r in rows if r["dispatcher"] == disp]
        if not mine:
            continue
        lines.append(f"[{disp}]")
        for r in sorted(mine, key=lambda r: (r["nbits"], r["batch"])):
            extra = "".join(f" {k}={v}" for k, v in r["detail"].items())
            lines.append(
                f"  nbits={r['nbits']} batch={r['batch']}{extra} -> "
                f"{r['choice']!r}  [{r['rule']}]  x{r['count']}")
    return lines
