"""Span profiling: wall-time spans distinguishing trace/compile from
execute, exportable as Chrome-trace JSON.

The retrace economics that motivate the serving engine (a fresh XLA
trace costs seconds, the op milliseconds) are invisible in aggregate
timings; spans make them first-class: callers wrap work in
``span(name, cat=...)`` (or record measured intervals via ``record``)
with ``cat`` one of ``CATEGORIES`` -- "trace" for tracing/compile
work, "execute" for steady-state device work -- and the buffer exports
to the ``chrome://tracing`` / Perfetto JSON array format, where the
two categories color differently.

When jax is already loaded, an enabled ``span`` also wraps the body in
``jax.profiler.TraceAnnotation`` so the same names show up inside a
jax device profile; nothing here imports jax otherwise (the obs
package stays stdlib-only).

Recording is a no-op when observability is off; timestamps are
``time.perf_counter`` relative to process start of this module, in
microseconds (what the trace viewer expects).
"""
from __future__ import annotations

import contextlib
import json
import sys
import time
from typing import List, Optional

from repro import config as _config

CATEGORIES = ("trace", "execute")

_T0 = time.perf_counter()
_spans: List[dict] = []
_MAX_SPANS = 65536                  # hard cap: drop, never grow unbounded


def enabled() -> bool:
    return bool(_config.get_override("observability"))


def record(name: str, cat: str, t0: float, dur_s: float, **args) -> None:
    """Record one measured interval (``t0`` from time.perf_counter).

    The low-level hook for callers that only know the category AFTER
    the work ran (the serving engine categorizes a flush as "trace"
    iff the jit cache missed)."""
    if not _config.get_override("observability"):
        return
    if cat not in CATEGORIES:
        raise ValueError(f"unknown span category {cat!r}; choose from "
                         f"{CATEGORIES}")
    if len(_spans) >= _MAX_SPANS:
        return
    _spans.append({
        "name": name, "cat": cat,
        "ts": (t0 - _T0) * 1e6, "dur": dur_s * 1e6,
        "args": {k: v for k, v in args.items()},
    })


@contextlib.contextmanager
def span(name: str, cat: str = "execute", **args):
    """Context manager form of ``record``; annotates via
    ``jax.profiler.TraceAnnotation`` when jax is already imported."""
    if not _config.get_override("observability"):
        yield
        return
    ann = contextlib.nullcontext()
    jax = sys.modules.get("jax")
    if jax is not None:
        try:
            ann = jax.profiler.TraceAnnotation(name)
        except Exception:  # noqa: BLE001 - annotation is best-effort
            pass
    t0 = time.perf_counter()
    try:
        with ann:
            yield
    finally:
        record(name, cat, t0, time.perf_counter() - t0, **args)


def spans() -> List[dict]:
    return list(_spans)


def clear() -> None:
    _spans.clear()


def chrome_trace() -> dict:
    """The span buffer as a Chrome-trace JSON object (complete-event
    "X" phase; load in chrome://tracing or ui.perfetto.dev)."""
    return {
        "displayTimeUnit": "ms",
        "traceEvents": [
            {"name": s["name"], "cat": s["cat"], "ph": "X",
             "ts": s["ts"], "dur": s["dur"], "pid": 1,
             "tid": 1 if s["cat"] == "trace" else 2, "args": s["args"]}
            for s in _spans],
    }


def write_chrome_trace(path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(), f, indent=1)
        f.write("\n")
    return path


def total_seconds(cat: Optional[str] = None) -> float:
    """Summed span wall time (optionally one category's) in seconds."""
    return sum(s["dur"] for s in _spans
               if cat is None or s["cat"] == cat) * 1e-6
