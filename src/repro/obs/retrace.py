"""Retrace alarm: the zero-retrace contract as a RUNTIME guard.

The serving engine's design invariant -- after ``warm()``, no request
mix may ever trigger a fresh jit trace -- used to live only in a test
assertion and a benchmark-internal assert.  This module makes it an
operational signal: when an armed caller (the engine, after warming)
sees an unexpected jit cache miss, it calls ``alarm(...)``, which

  1. ALWAYS increments the ``retraces_total`` metric (labeled by
     where/op/bits) -- even with observability off, because a retrace
     in production is a correctness-of-deployment bug, not a debug
     detail, and the counter is one dict update;
  2. applies the configured policy, ``repro.api.configure(
     on_retrace=...)``: "warn" (default) emits a ``RetraceWarning``,
     "raise" raises ``RetraceAlarm`` (CI / tests), "ignore" only
     counts.

``count(...)`` is the read side benchmarks and CI gate on (see
benchmarks/bench_serve.py: a warmed replay must report zero).
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro import config as _config
from repro.obs import metrics as _metrics

POLICIES = ("ignore", "warn", "raise")
DEFAULT_POLICY = "warn"

METRIC = "retraces_total"


class RetraceWarning(UserWarning):
    """An armed zero-retrace contract saw a fresh jit trace."""


class RetraceAlarm(RuntimeError):
    """on_retrace="raise" form of the same signal."""


def policy() -> str:
    """The active on_retrace policy (configure wins; default "warn")."""
    value = _config.get_override("on_retrace")
    return DEFAULT_POLICY if value is None else str(value)


def alarm(where: str, **labels) -> None:
    """Report one unexpected retrace at site ``where`` (labels such as
    op=/bits= identify the offending bucket)."""
    _metrics.REGISTRY.counter(
        METRIC, "unexpected jit retraces after warm()").inc(
        where=where, **labels)
    pol = policy()
    detail = "".join(f" {k}={v}" for k, v in sorted(labels.items()))
    msg = (f"unexpected jit retrace at {where}{detail}: the zero-retrace "
           f"contract is armed (warm() completed) but this shape/modulus "
           f"was never warmed -- each such trace costs seconds of "
           f"compile on the serving path")
    if pol == "raise":
        raise RetraceAlarm(msg)
    if pol == "warn":
        warnings.warn(msg, RetraceWarning, stacklevel=3)


def count(where: Optional[str] = None, **labels) -> int:
    """Total alarms so far (optionally filtered by site / labels)."""
    c = _metrics.REGISTRY.get(METRIC)
    if c is None:
        return 0
    flt = dict(labels)
    if where is not None:
        flt["where"] = where
    return int(c.total(**flt))
