"""Process-level metrics registry: counters, gauges, histograms.

The ONE home for the counters that used to live scattered across the
stack (``cache_stats()`` plain dicts, ``EngineStats`` attributes,
``# perf-gate`` stdout lines).  Prometheus-shaped on purpose -- named
metrics with label sets -- but in-process and stdlib-only: the core
modules tick these from inside dispatchers and the serving engine, so
nothing here may pull jax (or anything heavier than ``bisect``) into
the import graph.

Conventions
-----------
* A metric is identified by name; each distinct label set is one
  *series* under that name (``counter("dispatch_total").inc(op="mul",
  choice="ntt")`` and ``...inc(op="mul", choice="dot")`` are two
  series of one counter).
* Label values are stringified at ingestion so snapshots are
  JSON-serializable and series keys are stable.
* ``Histogram`` is bucketed (upper-edge bounds + overflow), tracking
  count/sum/min/max per series; quantiles come from linear
  interpolation inside the owning bucket -- exact at bucket edges,
  within one bucket width otherwise (tests/test_obs.py pins the math
  on known streams).

``REGISTRY`` is the process singleton the rest of the repo uses;
``repro.api.metrics()`` snapshots it (plus the arithmetic cache
counters) for callers.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, Iterable, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Default latency bounds: 5 buckets per decade from 10us to 100s --
# wide enough for interpret-mode CPU modexps AND real-TPU kernel calls.
LATENCY_BOUNDS_S = tuple(
    round(1e-5 * 10 ** (i / 5), 10) for i in range(36))


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


def _matches(key: LabelKey, flt: Dict[str, object]) -> bool:
    have = dict(key)
    return all(have.get(k) == str(v) for k, v in flt.items())


class _Metric:
    kind = "metric"

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        self.name = name
        self.help = help

    def snapshot(self) -> dict:
        raise NotImplementedError


class Counter(_Metric):
    """Monotone per-series counter.  ``inc(amount, **labels)``."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        super().__init__(name, help)
        self._series: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0) + amount

    def value(self, **labels) -> float:
        """Exact-label-set series value (0 when the series never ticked)."""
        return self._series.get(_label_key(labels), 0)

    def total(self, **label_filter) -> float:
        """Sum over every series whose labels INCLUDE ``label_filter``."""
        return sum(v for k, v in self._series.items()
                   if _matches(k, label_filter))

    def snapshot(self) -> dict:
        return {_label_str(k): v for k, v in sorted(self._series.items())}


class Gauge(_Metric):
    """Last-write-wins per-series value.  ``set(value, **labels)``."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        super().__init__(name, help)
        self._series: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        self._series[_label_key(labels)] = value

    def value(self, **labels) -> Optional[float]:
        return self._series.get(_label_key(labels))

    def snapshot(self) -> dict:
        return {_label_str(k): v for k, v in sorted(self._series.items())}


class _HistSeries:
    __slots__ = ("counts", "count", "sum", "vmin", "vmax")

    def __init__(self, nbuckets: int):
        self.counts = [0] * nbuckets
        self.count = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf


class Histogram(_Metric):
    """Bucketed histogram with interpolated quantiles.

    ``bounds`` are ascending bucket UPPER edges; values above the last
    bound land in an overflow bucket.  ``quantile(q)`` walks the
    cumulative counts to the owning bucket and interpolates linearly
    between its edges (clamped to the observed min/max, so single-value
    streams answer exactly).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",  # noqa: A002
                 bounds: Iterable[float] = LATENCY_BOUNDS_S):
        super().__init__(name, help)
        self.bounds = tuple(float(b) for b in bounds)
        if not self.bounds or any(
                b1 <= b0 for b0, b1 in zip(self.bounds, self.bounds[1:])):
            raise ValueError(
                f"histogram {name}: bounds must be non-empty ascending, "
                f"got {self.bounds}")
        self._series: Dict[LabelKey, _HistSeries] = {}

    def _get(self, labels) -> _HistSeries:
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.bounds) + 1)
        return s

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        s = self._get(labels)
        s.counts[bisect.bisect_left(self.bounds, v)] += 1
        s.count += 1
        s.sum += v
        s.vmin = min(s.vmin, v)
        s.vmax = max(s.vmax, v)

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(labels))
        return s.count if s else 0

    def quantile(self, q: float, **labels) -> Optional[float]:
        """Interpolated q-quantile (q in [0, 1]); None on an empty series."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {q}")
        s = self._series.get(_label_key(labels))
        if s is None or s.count == 0:
            return None
        target = q * s.count
        cum = 0
        for i, c in enumerate(s.counts):
            cum += c
            if cum >= target and c > 0:
                lo = self.bounds[i - 1] if i > 0 else min(s.vmin, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else s.vmax
                frac = (target - (cum - c)) / c
                return min(max(lo + frac * (hi - lo), s.vmin), s.vmax)
        return s.vmax

    def percentiles(self, qs=(0.5, 0.95, 0.99), **labels) -> dict:
        return {f"p{q * 100:g}": self.quantile(q, **labels) for q in qs}

    def snapshot(self) -> dict:
        out = {}
        for key, s in sorted(self._series.items()):
            labels = dict(key)
            out[_label_str(key)] = {
                "count": s.count,
                "sum": s.sum,
                "min": None if s.count == 0 else s.vmin,
                "max": None if s.count == 0 else s.vmax,
                **{k: v for k, v in self.percentiles(**labels).items()},
            }
        return out


class Registry:
    """Get-or-create metric store; one per process (``REGISTRY``)."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kwargs):  # noqa: A002
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kwargs)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:  # noqa: A002
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:  # noqa: A002
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",  # noqa: A002
                  bounds: Iterable[float] = LATENCY_BOUNDS_S) -> Histogram:
        return self._get(Histogram, name, help, bounds=bounds)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> list:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-serializable {"counters": {name: {labels: value}},
        "gauges": ..., "histograms": {name: {labels: {count/sum/min/
        max/p50/p95/p99}}}} -- the repro.api.metrics() payload."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            out[m.kind + "s"][name] = m.snapshot()
        return out

    def reset(self) -> None:
        self._metrics.clear()


REGISTRY = Registry()


def counter(name: str, help: str = "") -> Counter:  # noqa: A002
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:  # noqa: A002
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "",  # noqa: A002
              bounds: Iterable[float] = LATENCY_BOUNDS_S) -> Histogram:
    return REGISTRY.histogram(name, help, bounds=bounds)
