"""Unified observability layer: dispatch tracing, metrics, spans, and
the retrace alarm.

Four pillars, one switch:

  * ``repro.obs.trace``   -- structured dispatch-decision events from
    the multiply/divide/modexp tier choosers (bounded ring buffer,
    subscribable);
  * ``repro.obs.metrics`` -- process-level counters / gauges /
    histograms with labels (``REGISTRY``), absorbing the serving
    engine's stats and feeding ``repro.api.metrics()``;
  * ``repro.obs.spans``   -- wall-time spans split into "trace"
    (tracing/compile) vs "execute" categories, exportable as
    Chrome-trace JSON;
  * ``repro.obs.retrace`` -- the zero-retrace contract as a runtime
    guard (``configure(on_retrace="warn"|"raise"|"ignore")``).

Everything is near-zero-cost when off (the default): emit/record are
guarded no-ops, no events or spans are allocated.  Enable with
``repro.api.configure(observability=True)`` (scoped via its context-
manager form) or the ``enable()`` / ``disable()`` shorthands here.
The retrace counter is the one exception -- it always ticks, because a
post-warm retrace is an operational bug worth counting even when
nobody asked for tracing.

This package is import-light by design (stdlib + ``repro.config``
only): core dispatchers and configs call into it without pulling jax
into their import graphs.
"""
from __future__ import annotations

from repro import config as _config
from repro.obs import metrics, retrace, spans, trace
from repro.obs.metrics import REGISTRY, Counter, Gauge, Histogram
from repro.obs.retrace import RetraceAlarm, RetraceWarning
from repro.obs.spans import chrome_trace, span, write_chrome_trace
from repro.obs.trace import DispatchEvent, format_report, subscribe

# trace.events under its facade name (repro.obs.trace.events reads
# better fully qualified; bare "events" is ambiguous at package level)
dispatch_events = trace.events
dispatch_report = trace.report

__all__ = [
    "metrics", "trace", "spans", "retrace",
    "REGISTRY", "Counter", "Gauge", "Histogram",
    "RetraceAlarm", "RetraceWarning",
    "chrome_trace", "span", "write_chrome_trace",
    "DispatchEvent", "dispatch_events", "dispatch_report",
    "format_report", "subscribe",
    "enable", "disable", "enabled", "reset",
]


def enabled() -> bool:
    return bool(_config.get_override("observability"))


def enable() -> None:
    """Turn observability on process-wide (== configure(observability=
    True); use the configure context manager for scoped enabling)."""
    _config.set_overrides({"observability": True})


def disable() -> None:
    _config.set_overrides({"observability": None})


def reset() -> None:
    """Clear every buffer and the metrics registry (tests, CLI runs)."""
    trace.clear()
    spans.clear()
    metrics.REGISTRY.reset()
