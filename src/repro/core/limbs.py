"""Limb-array representation for large-number arithmetic (DigitsOnTurbo on TPU).

Conventions
-----------
* A big integer is an array of unsigned limbs in **little-endian** order:
  limb 0 is the least significant.  The limb axis is ALWAYS the last axis.
  Leading axes are batch ("lane") axes: on TPU the VPU's (8, 128) vreg grid
  plays the role that AVX-512's 8x64-bit lanes play in the paper, and the
  batch axis is how a TPU additionally amortizes the carry machinery over
  thousands of independent operations.

* Saturated radix: 32-bit limbs held in ``uint32``.  The paper uses 64-bit
  saturated limbs because x86-64's scalar ALU is 64-bit; the TPU VPU is a
  32-bit machine, so the TPU-native saturated radix is 2**32.

* Unsaturated radix ("digits"): ``digit_bits < 32`` digits held in uint32
  (or int8 for the MXU path).  This is the analogue of AVX-512IFMA's
  52-bits-in-64 representation (paper sec 2.1/3.3):
    - 16-in-32 for the VPU multiply path  (products fit exactly in uint32,
      mirroring vpmadd52's lo/hi split),
    - 7-in-8   for the MXU multiply path  (int8 x int8 -> int32 matmul),
    - (32-h)-in-32 for exact deferred-carry accumulation across replicas.

All host-side conversions are numpy; everything jit-able lives in add.py /
mul.py / modular.py.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

LIMB_BITS = 32
LIMB_DTYPE = np.uint32
LIMB_BASE = 1 << LIMB_BITS
LIMB_MAX = LIMB_BASE - 1


@dataclasses.dataclass(frozen=True)
class RadixSpec:
    """Describes a limb/digit representation.

    bits:    number of payload bits per stored element (radix = 2**bits).
    dtype:   storage dtype.
    name:    human-readable tag used by benchmarks / tables.
    """

    bits: int
    dtype: np.dtype
    name: str

    @property
    def base(self) -> int:
        return 1 << self.bits

    @property
    def mask(self) -> int:
        return self.base - 1

    def limbs_for_bits(self, nbits: int) -> int:
        return -(-nbits // self.bits)


SATURATED32 = RadixSpec(32, np.dtype(np.uint32), "saturated-32")
DIGIT16 = RadixSpec(16, np.dtype(np.uint32), "unsaturated-16in32")
DIGIT8_MXU = RadixSpec(7, np.dtype(np.int8), "unsaturated-7in8-mxu")


# ---------------------------------------------------------------------------
# Python int <-> limb array conversions (host side, arbitrary precision
# oracle glue; Python ints ARE the reference bignum implementation that every
# test checks against).
# ---------------------------------------------------------------------------

def int_to_limbs(value: int, m: int, bits: int = LIMB_BITS,
                 dtype=LIMB_DTYPE) -> np.ndarray:
    """Little-endian limb decomposition of a non-negative Python int."""
    if value < 0:
        raise ValueError("int_to_limbs expects a non-negative integer")
    if value >= (1 << (bits * m)):
        raise ValueError(f"value needs more than {m} limbs of {bits} bits")
    mask = (1 << bits) - 1
    out = np.zeros((m,), dtype=dtype)
    for i in range(m):
        out[i] = (value >> (bits * i)) & mask
    return out


def limbs_to_int(limbs: np.ndarray, bits: int = LIMB_BITS) -> int:
    """Inverse of int_to_limbs for a single (non-batched) limb vector."""
    limbs = np.asarray(limbs)
    value = 0
    for i in range(limbs.shape[-1]):
        value |= int(limbs[..., i]) << (bits * i)
    return value


def ints_to_batch(values: Sequence[int], m: int, bits: int = LIMB_BITS,
                  dtype=LIMB_DTYPE) -> np.ndarray:
    """(N,) python ints -> (N, m) limb batch."""
    return np.stack([int_to_limbs(v, m, bits, dtype) for v in values])


def batch_to_ints(batch: np.ndarray, bits: int = LIMB_BITS) -> list[int]:
    batch = np.asarray(batch)
    flat = batch.reshape(-1, batch.shape[-1])
    return [limbs_to_int(row, bits) for row in flat]


# ---------------------------------------------------------------------------
# Test-vector generation (paper sec 4: random + pathological cases)
# ---------------------------------------------------------------------------

def random_bigints(rng: np.random.Generator, batch: int, nbits: int) -> list[int]:
    """Uniform random nbits-bit integers (paper's "random" population)."""
    out = []
    for _ in range(batch):
        raw = rng.integers(0, 1 << 63, size=-(-nbits // 63), dtype=np.int64)
        v = 0
        for j, r in enumerate(raw):
            v |= int(r) << (63 * j)
        out.append(v & ((1 << nbits) - 1))
    return out


def pathological_pairs(nbits: int, bits: int = LIMB_BITS) -> list[tuple[int, int]]:
    """Adversarial operand pairs that maximize carry/borrow cascades.

    These mirror the paper's "pathological" population: full carry
    propagation, maxed-out limbs, zero limbs, alternating patterns.
    """
    full = (1 << nbits) - 1
    m = -(-nbits // bits)
    base = 1 << bits
    alt_lo = 0
    alt_hi = 0
    for i in range(m):
        if i % 2 == 0:
            alt_lo |= (base - 1) << (bits * i)
        else:
            alt_hi |= (base - 1) << (bits * i)
    return [
        (full, 1),                      # carry cascades through every limb
        (full, full),                   # all limbs generate
        (full - 1, 1),                  # almost-cascade (stops at limb 0)
        (1 << (nbits - 1), 1 << (nbits - 1)),  # single carry out of the top
        (alt_lo, alt_hi),               # alternating max/zero limbs
        (alt_lo, alt_lo),               # generate on even limbs only
        (0, 0),                         # all zero
        (full, 0),                      # max + zero (no carries at all)
    ]


# ---------------------------------------------------------------------------
# Radix repacking (saturated 32-bit limbs <-> smaller digits).
#
# General bit-exact repack: digit j of width ``to_bits`` covers bit range
# [to_bits*j, to_bits*(j+1)), which may straddle a 32-bit limb boundary.
# We gather the (at most two) source limbs per digit and shift/mask.  This is
# a pure host-side numpy helper AND has a jnp twin in mul.py for on-device
# conversion (the paper's "radix conversion" phase, Table 1/3).
# ---------------------------------------------------------------------------

def repack_np(arr: np.ndarray, from_bits: int, to_bits: int) -> np.ndarray:
    """Repack little-endian digit arrays between radices (numpy, batched).

    arr: (..., m_from) unsigned array with digits < 2**from_bits.
    Returns (..., m_to) uint64-safe array with digits < 2**to_bits.
    """
    arr = np.asarray(arr, dtype=np.uint64)
    m_from = arr.shape[-1]
    total_bits = from_bits * m_from
    m_to = -(-total_bits // to_bits)
    out = np.zeros(arr.shape[:-1] + (m_to,), dtype=np.uint64)
    mask = np.uint64((1 << to_bits) - 1)
    for j in range(m_to):
        lo_bit = to_bits * j
        src = lo_bit // from_bits
        off = lo_bit - src * from_bits
        val = arr[..., src] >> np.uint64(off)
        bits_have = from_bits - off
        k = 1
        while bits_have < to_bits and src + k < m_from:
            val = val | (arr[..., src + k] << np.uint64(bits_have))
            bits_have += from_bits
            k += 1
        out[..., j] = val & mask
    return out


def digits16_from_limbs32(limbs: np.ndarray) -> np.ndarray:
    """Fast path for the 32 -> 16 split (each limb -> lo16, hi16)."""
    limbs = np.asarray(limbs, dtype=np.uint32)
    lo = limbs & np.uint32(0xFFFF)
    hi = limbs >> np.uint32(16)
    return np.stack([lo, hi], axis=-1).reshape(*limbs.shape[:-1], -1)


def limbs32_from_digits16(digits: np.ndarray) -> np.ndarray:
    """Inverse of digits16_from_limbs32 (digits must be normalized < 2**16)."""
    digits = np.asarray(digits, dtype=np.uint32)
    if digits.shape[-1] % 2:
        digits = np.concatenate(
            [digits, np.zeros(digits.shape[:-1] + (1,), np.uint32)], axis=-1)
    pairs = digits.reshape(*digits.shape[:-1], -1, 2)
    return pairs[..., 0] | (pairs[..., 1] << np.uint32(16))
