"""RSA-style batched sign/verify on top of DoT modular arithmetic.

The OpenSSL-speed analogue (paper Fig. 5): throughput/latency of modexp-
bound crypto, batched across TPU lanes.  Key generation runs host-side
with Python integers (Miller-Rabin) -- the launcher's job, like loading
certificates; all per-message math runs in JAX via core.modular.

This module also provides the checkpoint-integrity signer used by
train/checkpoint.py (dogfooding: the framework's own fault-tolerance
layer rides on the paper's arithmetic).
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core import modular as M

U32 = jnp.uint32
DIGIT_BITS = 16


# ---------------------------------------------------------------------------
# host-side keygen (python ints)
# ---------------------------------------------------------------------------

_SMALL_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47]


def _is_probable_prime(n: int, rng: np.random.Generator, rounds: int = 12) -> bool:
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for _ in range(rounds):
        a = int(rng.integers(2, min(n - 2, 1 << 62)))
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def _gen_prime(bits: int, rng: np.random.Generator) -> int:
    while True:
        raw = L.random_bigints(rng, 1, bits)[0]
        cand = raw | (1 << (bits - 1)) | 1
        if _is_probable_prime(cand, rng):
            return cand


@dataclasses.dataclass(frozen=True)
class RSAKey:
    n: int
    e: int
    d: int
    bits: int
    p: int = 0                   # prime factors (0: unknown -- no CRT)
    q: int = 0

    @property
    def ctx(self) -> M.MontCtx:
        return M.mont_setup(self.n, self.bits)


def generate_key(bits: int = 512, seed: int = 0, e: int = 65537) -> RSAKey:
    rng = np.random.default_rng(seed)
    while True:
        p = _gen_prime(bits // 2, rng)
        q = _gen_prime(bits - bits // 2, rng)
        if p == q:
            continue
        n = p * q
        phi = (p - 1) * (q - 1)
        if np.gcd(e, 1) and phi % e != 0:
            try:
                d = pow(e, -1, phi)
            except ValueError:
                continue
            return RSAKey(n=n, e=e, d=d, bits=bits, p=p, q=q)


# ---------------------------------------------------------------------------
# batched sign / verify (JAX)
# ---------------------------------------------------------------------------

def messages_to_digits(msgs: list[int], key: RSAKey) -> jnp.ndarray:
    m_digits = key.ctx.m
    return jnp.asarray(np.stack(
        [L.int_to_limbs(msg % key.n, m_digits, DIGIT_BITS) for msg in msgs]))


def sign(msg_digits: jax.Array, key: RSAKey,
         backend: str | None = None) -> jax.Array:
    """s = m^d mod n, batched over leading axes.

    ``backend=None`` routes through core/modular's batch-aware modexp
    dispatch (MODEXP_DISPATCH): the fused full-ladder Pallas kernel for
    kernel-sized batches, the jnp windowed ladder below that."""
    bits = M.exp_bits_msb(key.d, key.n.bit_length())
    return M.mod_exp(msg_digits, jnp.asarray(bits), key.ctx,
                     backend=backend)


def verify(sig_digits: jax.Array, key: RSAKey,
           backend: str | None = None) -> jax.Array:
    """m = s^e mod n (fast public exponent; the windowed ladder picks a
    small window for the 17-bit e, see pick_modexp_window)."""
    bits = M.exp_bits_msb(key.e)
    return M.mod_exp(sig_digits, jnp.asarray(bits), key.ctx,
                     backend=backend)


def decrypt_crt(c_digits: jax.Array, key: RSAKey,
                backend: str | None = None) -> jax.Array:
    """m = c^d mod n via the Chinese Remainder Theorem: two HALF-SIZE
    modexps (c^{d mod p-1} mod p, c^{d mod q-1} mod q) recombined with
    Garner's formula -- ~4x fewer digit-multiply work than the full
    ladder, the standard RSA private-key optimization.  Both half-size
    modexps ride the windowed ladder via the same backend dispatch as
    sign/verify (``backend=None`` -> MODEXP_DISPATCH auto-select).

    The recombination runs on device on the division subsystem: p and q
    are HOST-known key constants, so every mod-p/mod-q reduction is a
    core/div.divmod_const (exact host reciprocal: one pipeline multiply
    + a branch-free fix -- no Newton chain in the hot path) and the
    cross-products ride the multiply pipeline.  Host-side: only the
    per-key constants (d mod p-1, d mod q-1, q^{-1} mod p).
    """
    from repro.core import div as DV

    if not (key.p and key.q):
        raise ValueError("decrypt_crt needs a key with known p, q factors")
    p, q = key.p, key.q
    ctx_p = M.mont_setup(p)
    ctx_q = M.mont_setup(q)
    mp, mq, mn = ctx_p.m, ctx_q.m, key.ctx.m
    dp_bits = jnp.asarray(M.exp_bits_msb(key.d % (p - 1), p.bit_length()))
    dq_bits = jnp.asarray(M.exp_bits_msb(key.d % (q - 1), q.bit_length()))
    p_dig = jnp.asarray(L.int_to_limbs(p, mp, DIGIT_BITS))
    q_dig = jnp.asarray(L.int_to_limbs(q, mq, DIGIT_BITS))
    qinv = pow(q, -1, p)
    qinv_dig = jnp.asarray(L.int_to_limbs(qinv, mp, DIGIT_BITS))

    c = jnp.asarray(c_digits, U32)
    c_p = DV.divmod_const(c, p)[1][..., :mp]                # c mod p
    c_q = DV.divmod_const(c, q)[1][..., :mq]
    m1 = M.mod_exp(c_p, dp_bits, ctx_p, backend=backend)    # (.., mp)
    m2 = M.mod_exp(c_q, dq_bits, ctx_q, backend=backend)    # (.., mq)

    # Garner: h = qinv * (m1 - m2) mod p;  m = m2 + h*q
    m2_p = DV.divmod_const(m2, p)[1][..., :mp]              # m2 mod p
    w = mp + 1
    t = DV.add_digits(DV._pad_to(m1, w), DV._pad_to(p_dig, w))
    t, _ = DV.sub_digits(t, DV._pad_to(m2_p, w))            # < 2p
    over = DV.ge_digits(t, DV._pad_to(p_dig, w))
    t = DV.sub_digits(t, DV._pad_to(p_dig, w) * over[..., None])[0]
    # q^-1 and q are host key constants: at huge key sizes these Garner
    # cross-products ride the prepared-operand NTT cache like the
    # divmod_const reductions above them
    prod = DV._mul_equalized(t[..., :mp], qinv_dig,
                             b_const=qinv)                  # (.., 2mp)
    h = DV.divmod_const(prod, p)[1][..., :mp]               # (.., mp)
    hq = DV._mul_equalized(h, q_dig, b_const=q)[..., :mn]   # h*q < n
    return DV.add_digits(DV._pad_to(m2, mn), hq)


def digest_int(data: bytes, bits: int) -> int:
    h = b""
    i = 0
    while len(h) * 8 < bits:
        h += hashlib.sha256(data + i.to_bytes(4, "big")).digest()
        i += 1
    return int.from_bytes(h, "big") % (1 << (bits - 1))
