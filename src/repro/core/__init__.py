"""repro.core: DigitsOnTurbo (DoT) large-number arithmetic in JAX.

The paper's primary contribution, restructured for TPU:
  add.py      -- 4-phase DoT addition/subtraction + prior-work baselines
  mul.py      -- vertical-and-crosswise multiplication (VPU + MXU paths),
                 schoolbook baseline, Karatsuba with a DoT base case
  modular.py  -- Montgomery multiplication / modular exponentiation (the
                 OpenSSL-integration analogue: batched RSA/DH primitives)
  exact_accum -- deferred-carry fixed-point accumulation: the paper's
                 technique as a distributed-training feature (bitwise
                 deterministic, order-invariant gradient reduction)
  limbs.py    -- representations + host-side conversions/test vectors
"""
from repro.core import limbs
from repro.core.add import (
    ADD_STRATEGIES,
    SUB_STRATEGIES,
    add_jit,
    add_carry_select,
    add_ksa,
    add_naive_simd,
    add_seq,
    add_two_level,
    dot_add,
    dot_add_unconditional,
    dot_sub,
    dot_sub_unconditional,
    sub_jit,
    sub_seq,
)
from repro.core.mul import (
    dot_mul,
    dot_mul_mxu,
    join_digits,
    mul_jit,
    mul_karatsuba,
    mul_limbs32,
    mul_schoolbook,
    normalize_digits,
    normalize_digits_scan,
    split_digits,
)
from repro.core import exact_accum, gcd, modular, pi, rsa
