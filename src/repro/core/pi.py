"""pi to N decimal digits via Machin's formula on DoT fixed-point bignums.

The GMPbench "pi" analogue (paper Fig. 4: +19.3% from faster add/sub/mul):
  pi = 16 arctan(1/5) - 4 arctan(1/239)
  arctan(1/x) = sum_k (-1)^k / ((2k+1) x^(2k+1))

Fixed point: F = value * B**m for radix B = 2**16 and m digits.  Each term
needs one division by a SMALL integer (x**2 <= 57121 and 2k+1) -- the
division subsystem's scalar fast path (core/div.div_small) -- plus one
DoT add/sub per term (core/div's digit add/sub helpers; the carry logic
lives THERE now, not here).

Decimal rendering runs ON DEVICE too: the fractional part is scaled by
10**n (one pipeline multiply) and converted with core/div.to_decimal's
divide-and-conquer divmod -- only the final digit array crosses to the
host.  The workload is therefore add/sub + div_small for the series and
mul + divmod for the output: every primitive the repo accelerates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core.div import (add_digits, div_small, mul_digits_via_pipeline,
                            sub_digits, to_decimal_digits)

U32 = jnp.uint32
DIGIT_BITS = 16


def arctan_inv(x: int, m_digits: int) -> jax.Array:
    """arctan(1/x) in fixed point with m 16-bit digits (value * B**m).

    Iterates until the term underflows to zero (dynamic while_loop; each
    iteration is one div_small + one DoT add/sub)."""
    # t0 = B**m / x
    fixed_one = jnp.zeros((m_digits + 1,), U32).at[m_digits].set(1)
    t0 = div_small(fixed_one, x)[..., :m_digits]
    x2 = jnp.uint32(x * x)

    def cond(state):
        t, total, k, sign = state
        return jnp.any(t != 0)

    def body(state):
        t, total, k, sign = state
        term = div_small(t, 2 * k + 1)
        total = jnp.where(sign == 1,
                          sub_digits(total, term)[0],
                          add_digits(total, term))
        t = div_small(t, x2)
        return t, total, k + 1, 1 - sign

    # first term: + t0 / 1
    total0 = t0
    t1 = div_small(t0, x2)
    state = (t1, total0, jnp.uint32(1), jnp.uint32(1))
    _, total, _, _ = jax.lax.while_loop(cond, body, state)
    return total


def _mul_small(x: jax.Array, s: int) -> jax.Array:
    """x * s for small s, WIDENED by one digit (holds the integer part)."""
    from repro.core.mul import normalize_digits
    prod = x * jnp.uint32(s)
    lo = prod & jnp.uint32(0xFFFF)
    hi = prod >> jnp.uint32(DIGIT_BITS)
    zeros1 = jnp.zeros(x.shape[:-1] + (1,), U32)
    out = jnp.concatenate([lo, zeros1], axis=-1)
    out = out.at[..., 1:].add(hi)
    return normalize_digits(out, DIGIT_BITS)


def pi_fixed_point(n_decimal: int, guard_digits: int = 4):
    """Machin's series on device: (pi * B**m as (m+1,) digits, m)."""
    bits_needed = int(n_decimal * np.log2(10)) + 16 * guard_digits
    m = -(-bits_needed // DIGIT_BITS)
    a5 = arctan_inv(5, m)
    a239 = arctan_inv(239, m)
    return sub_digits(_mul_small(a5, 16), _mul_small(a239, 4))[0], m


def pi_decimal_digits(n_decimal: int, guard_digits: int = 4):
    """(integer part, (n_decimal,) decimal fraction digits) -- both on
    device until the final host transfer.

    The fraction digits are floor(frac * 10**n / B**m) rendered by the
    divide-and-conquer base conversion; the scale-by-10**n is one
    pipeline multiply.
    """
    pi_fx, m = pi_fixed_point(n_decimal, guard_digits)
    int_part = pi_fx[..., m]                       # top digit: 3
    frac = pi_fx[..., :m]
    ten_n = 10 ** n_decimal
    nt = max(1, -(-ten_n.bit_length() // DIGIT_BITS))
    ten_arr = jnp.asarray(L.int_to_limbs(ten_n, nt, DIGIT_BITS))
    w = max(m, nt)
    # 10**n is host-known: at pi sizes this multiply rides the NTT tier,
    # where the prepared-operand cache skips the constant's transform
    scaled = mul_digits_via_pipeline(
        jnp.pad(frac, (0, w - m)), jnp.pad(ten_arr, (0, w - nt)),
        b_const=ten_n)
    y = scaled[..., m: m + nt]                     # floor(frac*10**n / B**m)
    return int_part, to_decimal_digits(y, n_decimal)


def pi_digits(n_decimal: int, guard_digits: int = 4) -> str:
    """Compute pi to n_decimal digits; returns "3.1415..." string."""
    int_part, dec = jax.jit(
        lambda nd=n_decimal, g=guard_digits: pi_decimal_digits(nd, g))()
    return f"{int(int_part)}." + "".join(
        str(d) for d in np.asarray(dec).tolist())


def pi_reference(n_decimal: int) -> str:
    """Host-side Python-int oracle (same Machin formula, exact)."""
    prec = n_decimal + 10
    scale = 10 ** prec

    def atan_inv(x):
        total = 0
        term = scale // x
        k = 0
        x2 = x * x
        while term:
            total += term // (2 * k + 1) if k % 2 == 0 else -(term // (2 * k + 1))
            term //= x2
            k += 1
        return total

    pi_val = 16 * atan_inv(5) - 4 * atan_inv(239)
    s = str(pi_val)
    return s[0] + "." + s[1:1 + n_decimal]
