"""pi to N decimal digits via Machin's formula on DoT fixed-point bignums.

The GMPbench "pi" analogue (paper Fig. 4: +19.3% from faster add/sub/mul):
  pi = 16 arctan(1/5) - 4 arctan(1/239)
  arctan(1/x) = sum_k (-1)^k / ((2k+1) x^(2k+1))

Fixed point: F = value * B**m for radix B = 2**16 and m digits.  Each term
needs one division by a SMALL integer (x**2 <= 57121 and 2k+1), which is a
digit-wise scan with a running remainder, plus one DoT add/sub per term --
the workload is dominated by exactly the primitives the paper accelerates.
All series state lives in JAX; only the final decimal rendering is host-
side Python.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core.mul import normalize_digits

U32 = jnp.uint32
DIGIT_BITS = 16
MASK = jnp.uint32(0xFFFF)


def div_small(x: jax.Array, s) -> jax.Array:
    """Exact floor-division of an m-digit fixed-point number by a small
    positive int s < 2**16: scan from the most significant digit with a
    running remainder (r*B + d < 2**32 stays exact in uint32)."""
    s = jnp.uint32(s)

    def step(r, d):
        cur = (r << jnp.uint32(DIGIT_BITS)) | d
        q = cur // s
        return cur - q * s, q

    x_t = jnp.moveaxis(x, -1, 0)[::-1]            # MSB first
    _, q_t = jax.lax.scan(step, jnp.zeros(x.shape[:-1], U32), x_t)
    return jnp.moveaxis(q_t[::-1], 0, -1)


def _widen_add(a, b):
    """Digit-domain (radix 2**16) add: lazy sum + deferred-carry resolve."""
    return normalize_digits(a + b, DIGIT_BITS)


def _widen_sub(a, b):
    """Digit-domain subtract, a >= b: radix complement + carry resolve
    (the mod-B**m carry drops off the top)."""
    comp = (MASK - b) & MASK
    t = (a + comp).at[..., 0].add(1)
    return normalize_digits(t, DIGIT_BITS)


def arctan_inv(x: int, m_digits: int) -> jax.Array:
    """arctan(1/x) in fixed point with m 16-bit digits (value * B**m).

    Iterates until the term underflows to zero (dynamic while_loop; each
    iteration is one div_small + one DoT add/sub)."""
    # t0 = B**m / x
    fixed_one = jnp.zeros((m_digits + 1,), U32).at[m_digits].set(1)
    t0 = div_small(fixed_one, x)[..., :m_digits]
    x2 = jnp.uint32(x * x)

    def cond(state):
        t, total, k, sign = state
        return jnp.any(t != 0)

    def body(state):
        t, total, k, sign = state
        term = div_small(t, 2 * k + 1)
        total = jnp.where(sign == 1,
                          _widen_sub(total, term),
                          _widen_add(total, term))
        t = div_small(t, x2)
        return t, total, k + 1, 1 - sign

    # first term: + t0 / 1
    total0 = t0
    t1 = div_small(t0, x2)
    state = (t1, total0, jnp.uint32(1), jnp.uint32(1))
    _, total, _, _ = jax.lax.while_loop(cond, body, state)
    return total


def _mul_small(x: jax.Array, s: int) -> jax.Array:
    """x * s for small s, WIDENED by one digit (holds the integer part)."""
    from repro.core.mul import normalize_digits
    prod = x * jnp.uint32(s)
    lo = prod & MASK
    hi = prod >> jnp.uint32(DIGIT_BITS)
    zeros1 = jnp.zeros(x.shape[:-1] + (1,), U32)
    out = jnp.concatenate([lo, zeros1], axis=-1)
    out = out.at[..., 1:].add(hi)
    return normalize_digits(out, DIGIT_BITS)


def pi_digits(n_decimal: int, guard_digits: int = 4) -> str:
    """Compute pi to n_decimal digits; returns "3.1415..." string."""
    bits_needed = int(n_decimal * np.log2(10)) + 16 * guard_digits
    m = -(-bits_needed // DIGIT_BITS)
    a5 = arctan_inv(5, m)
    a239 = arctan_inv(239, m)
    pi_fx = _widen_sub(_mul_small(a5, 16), _mul_small(a239, 4))
    # host-side decimal rendering
    val = L.limbs_to_int(np.asarray(pi_fx), DIGIT_BITS)
    scale = 1 << (DIGIT_BITS * m)
    int_part = val // scale
    frac = val - int_part * scale
    digits = []
    for _ in range(n_decimal):
        frac *= 10
        digits.append(str(frac // scale))
        frac %= scale
    return f"{int_part}." + "".join(digits)


def pi_reference(n_decimal: int) -> str:
    """Host-side Python-int oracle (same Machin formula, exact)."""
    prec = n_decimal + 10
    scale = 10 ** prec

    def atan_inv(x):
        total = 0
        term = scale // x
        k = 0
        x2 = x * x
        while term:
            total += term // (2 * k + 1) if k % 2 == 0 else -(term // (2 * k + 1))
            term //= x2
            k += 1
        return total

    pi_val = 16 * atan_inv(5) - 4 * atan_inv(239)
    s = str(pi_val)
    return s[0] + "." + s[1:1 + n_decimal]
