"""Batched Montgomery modular arithmetic over radix-2**16 digits.

The crypto-serving substrate (paper sec 4.4/4.5: DoTSSL): RSA / DH / DSA
reduce to modular exponentiation, which reduces to Montgomery multiply.
On TPU the SIMD win is the batch axis -- thousands of independent modexps
vectorized over VPU lanes -- while each CIOS iteration uses the same
deferred-carry structure as DoT (lazy uint32 digits, one carry-resolve
pass at the end) instead of per-step carry propagation.

Lazy-digit overflow analysis (why no per-iteration normalization):
  each CIOS iteration adds <= 4*(B-1) + carry < 5*2**16 to any digit, so
  after m iterations digits < 5*m*2**16 -- safe in uint32 for m <= 2**13
  (operands up to 128 Kbit, far beyond RSA sizes).

Exponentiation is a constant-time fixed-window (k-ary) ladder shared by
every device backend (_windowed_ladder): a 2**w-entry power table, w
squarings + one branch-free table gather per window -- ~nbits*(1 + 1/w)
+ 2**w modular multiplies instead of the bit-serial ladder's ~2*nbits,
with no data-dependent branching on exponent bits (matching how crypto
libraries avoid key-dependent timing).  On the "pallas" backend the
WHOLE ladder is one fused kernel launch (kernels/dot_modmul): residue,
modulus, and power table stay VMEM-resident across all steps.

Backend dispatch
----------------
Every public op takes ``backend`` (default: the module default, "jnp"):

  * ``reference`` -- host-side Python-int oracle (exact, slow; the
    ground truth every other backend is tested against),
  * ``jnp``       -- the pure-jnp formulation below (HBM round-trips the
    accumulator every CIOS scan step),
  * ``pallas``    -- the fused VMEM-resident kernel in
    kernels/dot_modmul (interpret mode on CPU, tiled on TPU),
  * ``barrett``   -- Barrett reduction (Mathemagix-style): precomputed
    mu = floor(B**2m / n), reduction = two pipeline multiplies + a
    bounded correction.  No Montgomery form, no parity restriction --
    handles EVEN moduli.  Montgomery setup rejects even n with a
    pointer here; mod_mul/mod_exp auto-route a BarrettCtx to a Barrett
    backend,
  * ``barrett_fused`` -- the same Barrett schedule as ONE fused Pallas
    launch per multiply / per FULL modexp ladder
    (kernels/dot_modmul's Barrett block: mul -> truncated mu-multiply
    -> q*n subtract -> two branch-free corrections, everything
    VMEM-resident) -- even moduli get the single-launch ladder too.

core/rsa.py, examples/rsa_crypto.py and benchmarks/bench_crypto.py all
route through this one API, so backends can be compared head-to-head.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core.mul import normalize_digits, normalize_digits_scan

U32 = jnp.uint32
DIGIT_BITS = 16
BASE = 1 << DIGIT_BITS
MASK = jnp.uint32(BASE - 1)

BACKENDS = ("reference", "jnp", "pallas", "barrett", "barrett_fused")
_DEFAULT_BACKEND = "jnp"


def set_default_backend(name: str) -> None:
    """Set the module-wide default backend for all modular ops."""
    global _DEFAULT_BACKEND
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; choose from {BACKENDS}")
    _DEFAULT_BACKEND = name


def get_default_backend() -> str:
    return _DEFAULT_BACKEND


def _resolve_backend(backend: str | None, ctx=None) -> str:
    backend = backend or _DEFAULT_BACKEND
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    # Even moduli carry a BarrettCtx; the Montgomery backends cannot
    # serve them, so auto-route to Barrett instead of failing deep in
    # a kernel: the fused Barrett kernel for "pallas" (the user asked
    # for a kernel), the jnp composition for "jnp".  The "reference"
    # oracle handles any parity and is kept.
    if isinstance(ctx, BarrettCtx):
        if backend == "pallas":
            return "barrett_fused"
        if backend == "jnp":
            return "barrett"
    return backend


@dataclasses.dataclass(frozen=True)
class MontCtx:
    """Host-side Montgomery context for an odd modulus n (R = B**m)."""
    m: int                       # digits
    n: int                       # python int modulus
    n0p: int                     # -n^{-1} mod B
    n_digits: np.ndarray         # (m,)
    r2_digits: np.ndarray        # R^2 mod n   (to enter Montgomery form)
    one_digits: np.ndarray       # R mod n     (Montgomery form of 1)


@functools.lru_cache(maxsize=128)
def mont_setup(n: int, nbits: int | None = None) -> MontCtx:
    """Host-side Montgomery constants, memoized per (n, nbits): callers
    like RSAKey.ctx rebuild the context on every access, so repeated
    setups (including the R**2 mod n bigint work) must be cache hits.
    The frozen dataclass and its arrays are shared -- treat as read-only.
    """
    if n % 2 == 0 or n <= 2:
        raise ValueError(
            f"Montgomery arithmetic requires an odd modulus > 2, got "
            f"n % 2 == {n % 2}; use barrett_setup / mod_setup (Barrett "
            f"reduction handles even moduli)")
    nbits = nbits or n.bit_length()
    m = -(-nbits // DIGIT_BITS)
    R = 1 << (DIGIT_BITS * m)
    n0p = (-pow(n, -1, BASE)) % BASE
    return MontCtx(
        m=m, n=n, n0p=n0p,
        n_digits=L.int_to_limbs(n, m, DIGIT_BITS),
        r2_digits=L.int_to_limbs((R * R) % n, m, DIGIT_BITS),
        one_digits=L.int_to_limbs(R % n, m, DIGIT_BITS),
    )


@dataclasses.dataclass(frozen=True)
class BarrettCtx:
    """Host-side Barrett context for ANY modulus n >= 2 (even or odd).

    mu = floor(B**2m / n) is the fixed-point reciprocal that turns
    reduction into two multiplies (van der Hoeven & Lecerf's SIMD-
    friendly companion to vectorized multiplication).
    """
    m: int                       # digits
    n: int                       # python int modulus
    mu: int                      # python int mu (host-known: the fixed
    #                              operands feed the prepared-NTT cache)
    n_digits: np.ndarray         # (m,)
    mu_digits: np.ndarray        # (m + 2,): mu = floor(B**2m / n)


@functools.lru_cache(maxsize=128)
def barrett_setup(n: int, nbits: int | None = None) -> BarrettCtx:
    """Memoized like mont_setup: _as_barrett promotes a MontCtx on EVERY
    Barrett-path call, and the B**2m // n bigint division is exactly the
    kind of host work that must not repeat per multiply."""
    if n < 2:
        raise ValueError("Barrett reduction requires a modulus >= 2")
    nbits = nbits or n.bit_length()
    m = -(-nbits // DIGIT_BITS)
    if n < BASE ** (m - 1):
        # the q_hat <= q <= q_hat + 2 bound (and the m+2-digit mu
        # sizing) both need the top declared digit nonzero
        raise ValueError(
            f"barrett_setup: nbits={nbits} over-declares the modulus "
            f"(n has {n.bit_length()} bits); Barrett's trial-quotient "
            f"bound needs the top digit nonzero -- pass nbits <= "
            f"{(-(-n.bit_length() // DIGIT_BITS)) * DIGIT_BITS}")
    mu = (BASE ** (2 * m)) // n
    return BarrettCtx(
        m=m, n=n, mu=mu,
        n_digits=L.int_to_limbs(n, m, DIGIT_BITS),
        mu_digits=L.int_to_limbs(mu, m + 2, DIGIT_BITS),
    )


def mod_setup(n: int, nbits: int | None = None):
    """Context for a modulus of either parity: MontCtx for odd n (the
    fast fused-kernel path), BarrettCtx for even n (auto-routed to the
    Barrett backend by mod_mul / mod_exp)."""
    if n % 2 == 1 and n > 2:
        return mont_setup(n, nbits)
    return barrett_setup(n, nbits)


def _as_barrett(ctx) -> BarrettCtx:
    if isinstance(ctx, BarrettCtx):
        return ctx
    return barrett_setup(ctx.n, ctx.m * DIGIT_BITS)   # memoized setup


def _barrett_reduce(x: jax.Array, ctx: BarrettCtx) -> jax.Array:
    """x mod n for (..., 2m) normalized digits with x < n * B**m
    (anything the product of two residues can produce).

    q_hat = floor(floor(x / B**(m-1)) * mu / B**(m+1)) underestimates
    q = floor(x / n) by at most 2 (classic Barrett bound; n >= B**(m-1)
    holds by construction of m), so r = x - q_hat*n < 3n and a masked
    while-loop finishes in <= 2 trips.  Both multiplies route through
    the autotuned pipeline (core/div.mul_digits_via_pipeline).
    """
    from repro.core import div as DV

    m = ctx.m
    x = jnp.asarray(x, U32)
    mu = jnp.asarray(ctx.mu_digits, U32)
    n_dig = jnp.asarray(ctx.n_digits, U32)

    t = x[..., m - 1:]                                 # floor(x / B**(m-1))
    # mu and n are host-known per context: both multiplies declare their
    # fixed operand so huge moduli hit the prepared-operand NTT cache
    q = DV._mul_equalized(t, mu, DIGIT_BITS,
                          b_const=ctx.mu)[..., m + 1: 2 * m + 2]
    p = DV._mul_equalized(q, n_dig, DIGIT_BITS,
                          b_const=ctx.n)[..., : 2 * m]   # q_hat*n <= x
    r, _ = DV.sub_digits(x, p, DIGIT_BITS)
    r = r[..., : m + 1]                                # r < 3n < B**(m+1)
    n_w = jnp.broadcast_to(DV._pad_to(n_dig, m + 1), r.shape)

    def cond(r):
        return jnp.any(DV.ge_digits(r, n_w, DIGIT_BITS) == 1)

    def body(r):
        over = DV.ge_digits(r, n_w, DIGIT_BITS)
        return DV.sub_digits(r, n_w * over[..., None], DIGIT_BITS)[0]

    r = jax.lax.while_loop(cond, body, r)
    return r[..., :m]


def barrett_mod_mul(a: jax.Array, b: jax.Array, ctx) -> jax.Array:
    """(a * b) mod n on (..., m) digit arrays (no Montgomery form)."""
    from repro.core import div as DV

    bctx = _as_barrett(ctx)
    x = DV._mul_equalized(jnp.asarray(a, U32), jnp.asarray(b, U32),
                          DIGIT_BITS)                  # (..., 2m)
    return _barrett_reduce(x, bctx)


def _barrett_mod_exp(base: jax.Array, exp_bits: jax.Array, ctx,
                     window: int | None = None,
                     unroll: bool = False) -> jax.Array:
    """Windowed constant-time ladder on plain residues (Barrett needs no
    domain transform: table entry 0 is the literal digit 1)."""
    bctx = _as_barrett(ctx)
    x = jnp.asarray(base, U32)
    one = jnp.zeros((bctx.m,), U32).at[0].set(1)
    return _windowed_ladder(
        lambda a, b: barrett_mod_mul(a, b, bctx), one, x, exp_bits,
        window, unroll=unroll)


def _ge(a: jax.Array, b: jax.Array) -> jax.Array:
    """a >= b on normalized digit arrays; returns (...,) bool."""
    # lexicographic from the most significant digit
    gt = a > b
    lt = a < b
    # highest index where digits differ decides
    idx = jnp.arange(a.shape[-1])
    diff = gt.astype(jnp.int32) - lt.astype(jnp.int32)
    # weight by position: the most significant nonzero diff wins
    def step(carry, x):
        d = x
        return jnp.where(d != 0, d, carry), None
    d_t = jnp.moveaxis(diff, -1, 0)
    out, _ = jax.lax.scan(step, jnp.zeros(a.shape[:-1], jnp.int32), d_t)
    return out >= 0


def _sub_mod(a: jax.Array, n_dig: jax.Array) -> jax.Array:
    """a - n on digit arrays (a >= n guaranteed by caller), normalized."""
    mask = MASK
    comp = (mask - n_dig) & mask
    t = a + comp
    t = t.at[..., 0].add(1)
    t = normalize_digits(t, DIGIT_BITS)
    # drop the implicit B**m carry: it lands beyond the array only if a>=n;
    # with equal lengths the carry out of the top digit vanishes mod B**m.
    return t


def _flatten_batch(x: jax.Array, m: int):
    """(..., m) -> ((N, m), batch_shape) for the 2-D kernel entry points."""
    batch_shape = x.shape[:-1]
    return x.reshape((-1, m)), batch_shape


def _mont_mul_jnp(a: jax.Array, b: jax.Array, ctx: MontCtx,
                  lazy: bool = True) -> jax.Array:
    """CIOS Montgomery product: a*b*R^{-1} mod n (pure-jnp backend).

    a, b: (..., m) normalized digits < 2**16, values < n.
    Sequential over the m digits of a (inherent to Montgomery); fully
    vectorized over the batch and the m-digit vector ops per iteration;
    digits stay lazy (deferred carries) across all iterations.

    lazy=False normalizes the accumulator EVERY iteration (the carry-
    chasing structure of non-DoT implementations); the benchmark harness
    uses it as the integration baseline (paper sec 4.4).
    """
    m = ctx.m
    n_dig = jnp.asarray(ctx.n_digits, U32)
    n0p = jnp.uint32(ctx.n0p)
    bits = jnp.uint32(DIGIT_BITS)

    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    batch_shape = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    acc0 = jnp.zeros(batch_shape + (m + 1,), U32)

    a_t = jnp.moveaxis(jnp.broadcast_to(a, batch_shape + (m,)), -1, 0)

    def step(acc, ai):
        # acc += a_i * b   (lo into j, hi into j+1) -- lazy adds
        prod = ai[..., None] * b                      # (..., m) exact u32
        lo = prod & MASK
        hi = prod >> bits
        acc = acc.at[..., :m].add(lo)
        acc = acc.at[..., 1:m + 1].add(hi)
        # u = (acc[0] mod B) * n0p mod B ; acc += u * n
        u = ((acc[..., 0] & MASK) * n0p) & MASK
        prod2 = u[..., None] * n_dig
        lo2 = prod2 & MASK
        hi2 = prod2 >> bits
        acc = acc.at[..., :m].add(lo2)
        acc = acc.at[..., 1:m + 1].add(hi2)
        # digit 0 is now 0 mod B; shift down one digit, carrying its high part
        c0 = acc[..., 0] >> bits
        acc = jnp.concatenate(
            [acc[..., 1:], jnp.zeros(batch_shape + (1,), U32)], axis=-1)
        acc = acc.at[..., 0].add(c0)
        if not lazy:
            # non-DoT baseline: resolve every carry immediately (sequential
            # per-digit pass each iteration, like the ADC-chain structure)
            acc = normalize_digits_scan(acc, DIGIT_BITS)
        return acc, None

    acc, _ = jax.lax.scan(step, acc0, a_t)
    acc = normalize_digits(acc, DIGIT_BITS)           # (..., m+1), t < 2n
    # conditional subtract: t >= n -> t - n
    n_ext = jnp.concatenate([n_dig, jnp.zeros((1,), U32)])
    ge = _ge(acc, jnp.broadcast_to(n_ext, acc.shape))
    sub = _sub_mod(acc, n_ext)[..., : m + 1]
    out = jnp.where(ge[..., None], sub, acc)
    return out[..., :m]


def _mont_mul_reference(a, b, ctx: MontCtx) -> jax.Array:
    """Host-side Python-int oracle (exact; defines correctness)."""
    from repro.kernels.dot_modmul import ref as _ref
    a = np.asarray(a, np.uint32)
    b = np.asarray(b, np.uint32)
    shape = np.broadcast_shapes(a.shape[:-1], b.shape[:-1]) + (ctx.m,)
    a2, batch_shape = _flatten_batch(np.broadcast_to(a, shape), ctx.m)
    b2, _ = _flatten_batch(np.broadcast_to(b, shape), ctx.m)
    out = _ref.mont_mul_ref(a2, b2, ctx.n)
    return jnp.asarray(out.reshape(batch_shape + (ctx.m,)))


def mont_mul(a: jax.Array, b: jax.Array, ctx: MontCtx, lazy: bool = True,
             backend: str | None = None) -> jax.Array:
    """CIOS Montgomery product a*b*R^{-1} mod n on (..., m) digit arrays,
    dispatched to the selected backend (see module docstring).

    ``lazy`` applies to the jnp backend only: lazy=False is the eager
    per-iteration-normalization measurement baseline (bench_gmp).  The
    pallas kernel is lazy by construction; reference is exact host math.
    """
    backend = _resolve_backend(backend, ctx)
    if backend in ("barrett", "barrett_fused"):
        raise ValueError(
            "mont_mul computes a*b*R^{-1} (Montgomery form); the Barrett "
            "backends have no R -- use mod_mul / mod_exp, which dispatch "
            "to Barrett multiplies on plain residues")
    if backend == "jnp":
        return _mont_mul_jnp(a, b, ctx, lazy)
    if backend == "pallas":
        from repro.kernels.dot_modmul import ops as _mops
        from repro.resilience import guard as _guard
        a = jnp.asarray(a, U32)
        b = jnp.asarray(b, U32)
        shape = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]) + (ctx.m,)
        a2, batch_shape = _flatten_batch(jnp.broadcast_to(a, shape), ctx.m)
        b2, _ = _flatten_batch(jnp.broadcast_to(b, shape), ctx.m)
        out = _guard.run("montmul", ctx.m * DIGIT_BITS, [
            ("pallas", lambda: _mops.dot_mont_mul(a2, b2, ctx)),
            ("jnp", lambda: _mont_mul_jnp(a2, b2, ctx, lazy)),
        ])
        return out.reshape(batch_shape + (ctx.m,))
    return _mont_mul_reference(a, b, ctx)


def to_mont(x: jax.Array, ctx: MontCtx,
            backend: str | None = None) -> jax.Array:
    return mont_mul(x, jnp.asarray(ctx.r2_digits, U32), ctx,
                    backend=backend)


def from_mont(x: jax.Array, ctx: MontCtx,
              backend: str | None = None) -> jax.Array:
    one = jnp.zeros((ctx.m,), U32).at[0].set(1)
    return mont_mul(x, one, ctx, backend=backend)


def _mod_mul_reference(a, b, ctx) -> jax.Array:
    """Host-side Python-int (a*b) mod n oracle (any modulus parity)."""
    a = np.asarray(a, np.uint32)
    b = np.asarray(b, np.uint32)
    shape = np.broadcast_shapes(a.shape[:-1], b.shape[:-1]) + (ctx.m,)
    a2, batch_shape = _flatten_batch(np.broadcast_to(a, shape), ctx.m)
    b2, _ = _flatten_batch(np.broadcast_to(b, shape), ctx.m)
    out = np.stack([
        L.int_to_limbs((L.limbs_to_int(a2[i], DIGIT_BITS)
                        * L.limbs_to_int(b2[i], DIGIT_BITS)) % ctx.n,
                       ctx.m, DIGIT_BITS)
        for i in range(a2.shape[0])])
    return jnp.asarray(out.reshape(batch_shape + (ctx.m,)))


def mod_mul(a: jax.Array, b: jax.Array, ctx,
            backend: str | None = None) -> jax.Array:
    """Plain modular product.  Montgomery backends enter/leave Montgomery
    form; the Barrett backend (or any BarrettCtx, e.g. an even modulus
    from mod_setup) multiplies and reduces directly."""
    backend = _resolve_backend(backend, ctx)
    if backend == "barrett":
        return barrett_mod_mul(a, b, ctx)
    if backend == "barrett_fused":
        from repro.kernels.dot_modmul import ops as _mops
        from repro.resilience import guard as _guard
        bctx = _as_barrett(ctx)
        a = jnp.asarray(a, U32)
        b = jnp.asarray(b, U32)
        shape = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]) + (bctx.m,)
        a2, batch_shape = _flatten_batch(jnp.broadcast_to(a, shape), bctx.m)
        b2, _ = _flatten_batch(jnp.broadcast_to(b, shape), bctx.m)
        out = _guard.run("modmul", bctx.m * DIGIT_BITS, [
            ("barrett_fused", lambda: _mops.dot_barrett_mul(a2, b2, bctx)),
            ("barrett", lambda: barrett_mod_mul(a2, b2, bctx)),
        ])
        return out.reshape(batch_shape + (bctx.m,))
    if backend == "reference" and isinstance(ctx, BarrettCtx):
        return _mod_mul_reference(a, b, ctx)    # no Montgomery form exists
    return from_mont(
        mont_mul(to_mont(a, ctx, backend), to_mont(b, ctx, backend), ctx,
                 backend=backend), ctx, backend)


def _windowed_ladder(mm, one, x, exp_bits, window: int | None = None,
                     unroll: bool = False) -> jax.Array:
    """The ONE fixed-window (k-ary) constant-time exponentiation schedule
    shared by every device backend (jnp Montgomery, Barrett; the fused
    Pallas kernel runs the same schedule inside one launch).

    ``mm(a, b)`` is the backend's modular multiply on (..., m) digit
    arrays in its own domain; ``one`` is the multiplicative identity in
    that domain (R mod n for Montgomery, the digit 1 for Barrett); ``x``
    is the base already in-domain.  Schedule per ``exp_bits`` (MSB-first
    bits, (nbits,) or (..., nbits)):

      * build the 2**w-entry power table t[j] = x**j (2**w - 2 mults),
      * res := t[window 0]  (branch-free gather -- saves the w identity
        squarings a pad-with-leading-zeros ladder would burn, which is
        also what keeps the multiply count under nbits*(1 + 1/w) + 2**w
        for ALL nbits, not just multiples of w),
      * per remaining window: w squarings, then one multiply by the
        gathered table entry -- square always, multiply always; the
        exponent only ever feeds branch-free gather indices, never
        control flow.

    ``unroll=True`` replaces the lax.scan over windows with a Python
    loop so trace-time mm() calls == runtime modular multiplies (the
    call-counting test + tiny-exponent callers); results are identical.
    """
    from repro.configs.dot_bignum import pick_modexp_window
    from repro.kernels.common.windows import exponent_windows

    eb = jnp.asarray(exp_bits, U32)
    nbits = eb.shape[-1]
    w = int(window if window is not None else pick_modexp_window(nbits))
    if w < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    x = jnp.asarray(x, U32)
    batch_shape = jnp.broadcast_shapes(x.shape[:-1], eb.shape[:-1])
    m = x.shape[-1]
    x = jnp.broadcast_to(x, batch_shape + (m,))
    wv = exponent_windows(
        jnp.broadcast_to(eb, batch_shape + (nbits,)), w)   # (..., nwin)
    nwin = wv.shape[-1]

    table = [jnp.broadcast_to(jnp.asarray(one, U32), x.shape), x]
    for _ in range(2, 1 << w):
        table.append(mm(table[-1], x))
    tab = jnp.stack(table[: 1 << w], axis=-2)              # (..., 2**w, m)

    def select(d):
        idx = d.astype(jnp.int32)[..., None, None]         # (..., 1, 1)
        return jnp.take_along_axis(tab, idx, axis=-2)[..., 0, :]

    def step(res, d):
        for _ in range(w):
            res = mm(res, res)
        return mm(res, select(d)), None

    res = select(wv[..., 0])
    if unroll:
        for j in range(1, nwin):
            res, _ = step(res, wv[..., j])
    elif nwin > 1:
        wv_t = jnp.moveaxis(wv[..., 1:], -1, 0)            # (nwin-1, ...)
        res, _ = jax.lax.scan(step, res, wv_t)
    return res


def _mod_exp_jnp(base: jax.Array, exp_bits: jax.Array, ctx: MontCtx,
                 lazy: bool = True, window: int | None = None,
                 unroll: bool = False) -> jax.Array:
    x = to_mont(jnp.asarray(base, U32), ctx, backend="jnp")
    one = jnp.asarray(ctx.one_digits, U32)
    res = _windowed_ladder(
        lambda a, b: _mont_mul_jnp(a, b, ctx, lazy), one, x, exp_bits,
        window, unroll=unroll)
    return from_mont(res, ctx, backend="jnp")


def _bits_to_int(bits: np.ndarray) -> int:
    e = 0
    for v in bits:
        e = (e << 1) | int(v)
    return e


def _mod_exp_reference(base, exp_bits, ctx: MontCtx) -> jax.Array:
    from repro.kernels.dot_modmul import ref as _ref
    base = np.asarray(base, np.uint32)
    eb = np.asarray(exp_bits, np.uint32)
    b2, batch_shape = _flatten_batch(base, ctx.m)
    if eb.ndim == 1:
        out = _ref.mod_exp_ref(b2, _bits_to_int(eb), ctx.n)
    else:
        eb2 = np.broadcast_to(eb, batch_shape + (eb.shape[-1],))
        eb2 = eb2.reshape((-1, eb.shape[-1]))
        out = np.stack(
            [_ref.mod_exp_ref(b2[i:i + 1], _bits_to_int(eb2[i]), ctx.n)[0]
             for i in range(b2.shape[0])])
    return jnp.asarray(out.reshape(batch_shape + (ctx.m,)))


def _mod_exp_reference_cb(b2: jax.Array, eb: jax.Array, ctx) -> jax.Array:
    """The host oracle as a jit-safe tier: the guarded dispatchers run at
    trace time, where b2/eb are tracers, so the python-int recompute is
    deferred to runtime via pure_callback."""
    def _host(base_np, eb_np):
        return np.asarray(_mod_exp_reference(base_np, eb_np, ctx),
                          np.uint32)
    shape = jax.ShapeDtypeStruct(b2.shape[:-1] + (ctx.m,), np.uint32)
    return jax.pure_callback(_host, shape, b2, eb)


def select_modexp_backend(nbits: int, batch: int = 1, ebits: int = 0,
                          ctx=None) -> str:
    """Batch-aware modexp dispatch (configs/dot_bignum.MODEXP_DISPATCH),
    the modexp twin of core/mul.select_method.

    The fused full-ladder kernels amortize over the batch axis only, so
    tiny batches (and tiny exponents, where the table build dominates)
    take the composition ladders -- but the floor is
    ``packed_min_batch``, not a full tile: the kernel wrappers pad
    sub-tile batches up to kernels/common/tiling.MIN_TILE and the
    padded lanes ride the sublane axis for free.  A BarrettCtx (even
    modulus) routes to the fused Barrett ladder in the same regime and
    to the jnp Barrett composition below it.  A
    ``repro.api.configure(modexp_backend=...)`` override wins over
    everything (ops knob for A/B experiments without code changes); the
    REPRO_MODEXP_BACKEND env var is its deprecated alias."""
    from repro import config as _rc
    from repro.configs.dot_bignum import MODEXP_DISPATCH as cfg
    from repro.obs import trace as _trace

    override = _rc.resolve("modexp_backend", BACKENDS, "modexp backend")
    fused_ok = (batch >= cfg.packed_min_batch
                and nbits <= cfg.fused_max_bits
                and ebits >= cfg.fused_min_exp_bits)
    detail = {"ebits": ebits, "fused_ok": fused_ok}
    if override:
        choice, rule = _resolve_backend(override, ctx), "override"
    elif isinstance(ctx, BarrettCtx):
        choice = "barrett_fused" if fused_ok else "barrett"
        rule = "barrett_ctx_fused" if fused_ok else "barrett_ctx"
    elif _DEFAULT_BACKEND != "jnp":
        # an explicit set_default_backend() choice wins over the
        # size-based dispatch (force "jnp" via backend= or the env var)
        choice, rule = _DEFAULT_BACKEND, "default_backend"
    elif fused_ok:
        choice, rule = "pallas", "fused_thresholds"
    else:
        choice, rule = "jnp", "below_fused_thresholds"
    _trace.emit("modexp", nbits, batch, choice, rule, **detail)
    return choice


def mod_exp(base: jax.Array, exp_bits: jax.Array, ctx,
            lazy: bool = True, backend: str | None = None,
            window: int | None = None) -> jax.Array:
    """base ** e mod n via the fixed-window constant-time ladder.

    base: (..., m) digits; exp_bits: (nbits,) or (..., nbits) uint32/int32
    bits MSB-first.  Every backend runs the same windowed schedule
    (see _windowed_ladder): ~nbits * (1 + 1/w) + 2**w modular multiplies
    instead of the bit-serial ladder's ~2 * nbits, exponent bits only
    ever feeding branch-free table gathers.  ``window`` overrides the
    config-picked window size w (configs/dot_bignum.pick_modexp_window).

    ``backend=None`` auto-selects via select_modexp_backend: the fused
    full-ladder Pallas kernel (ONE launch per modexp, power table
    VMEM-resident across all steps) for kernel-sized batches, the jnp
    windowed composition below that; a BarrettCtx (even modulus)
    auto-routes to the Barrett ladder.  ``lazy`` applies to the jnp
    backend only (see mont_mul)."""
    eb = jnp.asarray(exp_bits, U32)
    if backend is None:
        batch = 1
        for d in jnp.broadcast_shapes(jnp.shape(base)[:-1], eb.shape[:-1]):
            batch *= int(d)
        backend = select_modexp_backend(
            ctx.m * DIGIT_BITS, batch, ebits=eb.shape[-1], ctx=ctx)
    else:
        backend = _resolve_backend(backend, ctx)
    if backend == "barrett":
        return _barrett_mod_exp(base, exp_bits, ctx, window)
    if backend == "jnp":
        return _mod_exp_jnp(base, exp_bits, ctx, lazy, window)
    if backend in ("pallas", "barrett_fused"):
        from repro.kernels.dot_modmul import ops as _mops
        from repro.resilience import guard as _guard
        kctx = _as_barrett(ctx) if backend == "barrett_fused" else ctx
        base = jnp.asarray(base, U32)
        # broadcast BOTH operands to the joint batch shape before
        # flattening (shared base x per-lane exponents and vice versa)
        shape = jnp.broadcast_shapes(
            base.shape[:-1], eb.shape[:-1]) + (kctx.m,)
        b2, batch_shape = _flatten_batch(
            jnp.broadcast_to(base, shape), kctx.m)
        if eb.ndim > 1:
            eb = jnp.broadcast_to(
                eb, batch_shape + (eb.shape[-1],)).reshape(-1, eb.shape[-1])
        eb2 = eb
        if backend == "barrett_fused":
            tiers = [
                ("barrett_fused", lambda: _mops.dot_barrett_mod_exp(
                    b2, eb2, kctx, window=window)),
                ("barrett", lambda: _barrett_mod_exp(b2, eb2, kctx, window)),
                ("reference", lambda: _mod_exp_reference_cb(b2, eb2, kctx)),
            ]
        else:
            tiers = [
                ("pallas", lambda: _mops.dot_mod_exp(
                    b2, eb2, kctx, window=window)),
                ("jnp", lambda: _mod_exp_jnp(b2, eb2, kctx, lazy, window)),
                ("reference", lambda: _mod_exp_reference_cb(b2, eb2, kctx)),
            ]
        out = _guard.run("modexp", kctx.m * DIGIT_BITS, tiers)
        return out.reshape(batch_shape + (kctx.m,))
    return _mod_exp_reference(base, exp_bits, ctx)


def exp_bits_msb(e: int, nbits: int | None = None) -> np.ndarray:
    """MSB-first bit array of e, padded (never truncated) to nbits."""
    if e < 0:
        raise ValueError(f"exp_bits_msb: exponent must be >= 0, got {e}")
    nbits = nbits or max(1, e.bit_length())
    if e.bit_length() > nbits:
        raise ValueError(
            f"exp_bits_msb: e needs {e.bit_length()} bits but nbits={nbits} "
            f"-- refusing to silently truncate the exponent")
    return np.array([(e >> (nbits - 1 - i)) & 1 for i in range(nbits)],
                    np.uint32)
