"""DoT multiplication (paper Algorithm 2) and baselines, adapted to TPU.

The paper's "vertical and crosswise" (VnC) organization exposes all m**2
partial products as independent work; on AVX-512 this feeds both IFMA ports.
On TPU we map the same structure two ways:

  * VPU path (``dot_mul``):  digits are radix 2**16 held in uint32 --- the
    TPU-native analogue of IFMA's 52-in-64 unsaturated radix.  A digit
    product fits *exactly* in uint32, so ``simd_mul_lo/hi`` (Alg. 2 lines
    16-17) become a single uint32 multiply plus mask/shift.  Column
    alignment (Phase 3) is a static skew-reshape; column reduction
    (Phase 4) is a vector sum; Phase 5's carry pass is a deferred-carry
    while-loop that converges in ~2 passes for random inputs (the
    multiplicative twin of DoT-add's Phase 4 rarity argument).

  * MXU path (``dot_mul_mxu``): the column sums ARE a convolution of the
    digit sequences, and a convolution is a (banded Toeplitz) matmul.  With
    radix 2**7 digits in int8 and int32 accumulation this runs on the MXU
    systolic array --- a genuinely TPU-native realization of the paper's
    insight (the MXU's 128x128 systolic grid replaces the two IFMA ports;
    every partial product is an independent MAC cell).  This is the
    beyond-paper optimization evaluated in EXPERIMENTS.md.

  * ``mul_schoolbook`` reproduces Gueron & Krasnov's shared-accumulator
    dependency structure (scan over b_j with a read-modify-write
    accumulator) as the baseline of Table 4.

  * ``karatsuba`` recurses with DoT as the base case, mirroring the DoTMP
    integration (paper sec 3.3): faster base-case multiply plus faster
    add/sub accelerate the whole recursion.

Digit conventions: little-endian, last axis; uint32 storage with digits
< 2**digit_bits ("normalized") unless a function documents a lazy range.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common.vnc import skew as _skew

U32 = jnp.uint32
I32 = jnp.int32

DIGIT_BITS = 16
DIGIT_MASK = jnp.uint32((1 << DIGIT_BITS) - 1)

MXU_DIGIT_BITS = 7


# ---------------------------------------------------------------------------
# Radix conversion (on-device twin of limbs.repack_np) --- the paper's
# "radix conversion" phase (Tables 1 and 3).
# ---------------------------------------------------------------------------

def split_digits(limbs: jax.Array, to_bits: int) -> jax.Array:
    """(..., m) uint32 limbs -> (..., m_to) uint32 digits < 2**to_bits."""
    assert 1 <= to_bits <= 32
    m_from = limbs.shape[-1]
    total = 32 * m_from
    m_to = -(-total // to_bits)
    j = np.arange(m_to)
    lo_bit = to_bits * j
    src = lo_bit // 32
    off = lo_bit % 32
    need2 = (off + to_bits > 32) & (src + 1 < m_from)
    src2 = np.minimum(src + 1, m_from - 1)
    sh2 = np.where(off > 0, 32 - off, 0).astype(np.uint32)

    limbs = jnp.asarray(limbs, U32)
    v1 = limbs[..., src] >> jnp.asarray(off, U32)
    v2 = jnp.where(jnp.asarray(need2),
                   limbs[..., src2] << jnp.asarray(sh2, U32),
                   jnp.uint32(0))
    mask = jnp.uint32((1 << to_bits) - 1)
    return (v1 | v2) & mask


def join_digits(digits: jax.Array, from_bits: int, m_out: int) -> jax.Array:
    """(..., n) normalized digits < 2**from_bits -> (..., m_out) uint32 limbs.

    Limb i gathers the digits overlapping bit range [32i, 32(i+1)); each
    contributes via a static shift (slot k enumerates the at-most
    ceil(32/from_bits)+1 overlapping digits).
    """
    assert 1 <= from_bits <= 32
    n = digits.shape[-1]
    digits = jnp.asarray(digits, U32)
    i = np.arange(m_out)
    max_slots = -(-32 // from_bits) + 1
    acc = jnp.zeros(digits.shape[:-1] + (m_out,), U32)
    for k in range(max_slots):
        d = 32 * i // from_bits + k          # digit feeding limb i, slot k
        sh = from_bits * d - 32 * i          # digit d's bit offset in limb i
        valid = (d < n) & (sh < 32)          # sh >= -from_bits always
        d_c = np.minimum(d, n - 1)
        vals = digits[..., d_c]
        left = np.clip(sh, 0, 31).astype(np.uint32)
        right = np.clip(-sh, 0, 31).astype(np.uint32)
        contrib = jnp.where(jnp.asarray(sh >= 0),
                            vals << jnp.asarray(left, U32),
                            vals >> jnp.asarray(right, U32))
        contrib = jnp.where(jnp.asarray(valid), contrib, jnp.uint32(0))
        acc = acc | contrib
    return acc


# ---------------------------------------------------------------------------
# Phase 5: carry normalization of column sums.
# ---------------------------------------------------------------------------

def normalize_digits(cols: jax.Array, digit_bits: int = DIGIT_BITS) -> jax.Array:
    """Deferred-carry normalization (DoT-style): repeat the O(1)-depth
    vector pass ``c <- (c & mask) + shift_up(c >> bits)`` until no digit
    exceeds the radix.  Random inputs converge in <= 2-3 passes; a
    pathological all-max chain degrades gracefully to O(m) passes, exactly
    mirroring DoT-add's common/rare split.  Total value is invariant and the
    top digit provably never overflows when the array is wide enough to hold
    the result (see DESIGN.md "Phase-5 invariant").
    """
    mask = jnp.uint32((1 << digit_bits) - 1)
    bits = jnp.uint32(digit_bits)

    def cond(c):
        return jnp.any(c > mask)

    def body(c):
        carry = c >> bits
        low = c & mask
        shifted = jnp.concatenate(
            [jnp.zeros(c.shape[:-1] + (1,), U32), carry[..., :-1]], axis=-1)
        return low + shifted

    return jax.lax.while_loop(cond, body, jnp.asarray(cols, U32))


def normalize_digits_scan(cols: jax.Array,
                          digit_bits: int = DIGIT_BITS) -> jax.Array:
    """Sequential Phase-5 pass (paper Alg. 2 lines 38-41), for baselines."""
    mask = jnp.uint32((1 << digit_bits) - 1)
    bits = jnp.uint32(digit_bits)

    def step(carry, col):
        t = col + carry
        return t >> bits, t & mask

    cols_t = jnp.moveaxis(jnp.asarray(cols, U32), -1, 0)
    carry0 = jnp.zeros(cols.shape[:-1], U32)
    _, out_t = jax.lax.scan(step, carry0, cols_t)
    return jnp.moveaxis(out_t, 0, -1)


# ---------------------------------------------------------------------------
# The skew trick (Phase 3's column alignment as a static reshape) is
# ``_skew``, shared with the kernel layer: kernels/common/vnc.skew.
# out[..., i, i+j] = mat[..., i, j]; anti-diagonal sums become column sums.
# ---------------------------------------------------------------------------
# DoT multiplication (Algorithm 2) --- VPU path, radix 2**16.
# ---------------------------------------------------------------------------

def dot_mul(a: jax.Array, b: jax.Array, digit_bits: int = DIGIT_BITS,
            normalize: str = "dot") -> jax.Array:
    """(..., m) x (..., m) normalized digits -> (..., 2m) normalized digits.

    Phase 1 (gather)      : implicit --- the broadcasted outer product
                            enumerates every (i, j) pair.
    Phase 2 (products)    : one uint32 multiply; lo/hi split replaces
                            vpmadd52lo/hi.  All m**2 products independent.
    Phase 3 (align)       : skew-reshape puts product (i, j) in column i+j
                            (hi parts in column i+j+1).
    Phase 4 (reduce)      : vector sum over the (independent) row axis.
    Phase 5 (carry pass)  : deferred-carry normalization.
    """
    assert digit_bits <= 16, "digit products must fit in uint32"
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    m = a.shape[-1]
    assert b.shape[-1] == m

    prod = a[..., :, None] * b[..., None, :]          # (..., m, m) exact
    lo = prod & DIGIT_MASK if digit_bits == 16 else prod & jnp.uint32((1 << digit_bits) - 1)
    hi = prod >> jnp.uint32(digit_bits)

    lo_cols = _skew(lo).sum(axis=-2)                   # (..., 2m-1)
    hi_cols = _skew(hi).sum(axis=-2)

    zeros1 = jnp.zeros(a.shape[:-1] + (1,), U32)
    cols = jnp.concatenate([lo_cols, zeros1], axis=-1)         # (..., 2m)
    cols = cols + jnp.concatenate([zeros1, hi_cols], axis=-1)  # hi -> c+1

    if normalize == "dot":
        return normalize_digits(cols, digit_bits)
    return normalize_digits_scan(cols, digit_bits)


# ---------------------------------------------------------------------------
# MXU path: column sums as an int8 x int8 -> int32 Toeplitz matmul.
# ---------------------------------------------------------------------------

def dot_mul_mxu(a: jax.Array, b: jax.Array,
                digit_bits: int = MXU_DIGIT_BITS) -> jax.Array:
    """(..., m) digits < 2**7 (any int dtype) -> (..., 2m) normalized digits.

    cols[c] = sum_{i+j=c} a_i * b_j  ==  a (1 x m) @ T (m x 2m-1),
    T[i, i+j] = b_j.  int8 operands with int32 accumulation target the MXU.
    Column sums < m * 127**2, exact in int32 for m < 2**17.
    """
    m = a.shape[-1]
    a8 = jnp.asarray(a, jnp.int8)
    b8 = jnp.asarray(b, jnp.int8)
    bt = jnp.broadcast_to(b8[..., None, :], b8.shape[:-1] + (m, m))
    T = _skew(bt)                                      # (..., m, 2m-1)
    cols = jnp.einsum("...i,...ic->...c", a8, T,
                      preferred_element_type=I32)      # MXU: int8 -> int32
    zeros1 = jnp.zeros(cols.shape[:-1] + (1,), I32)
    cols = jnp.concatenate([cols, zeros1], axis=-1).astype(U32)
    return normalize_digits(cols, digit_bits)


# ---------------------------------------------------------------------------
# Baseline: schoolbook with a shared accumulator (Gueron & Krasnov's RAW
# chain, Table 4).  scan(acc <- acc + row_j) serializes on the accumulator.
# ---------------------------------------------------------------------------

def mul_schoolbook(a: jax.Array, b: jax.Array,
                   digit_bits: int = DIGIT_BITS) -> jax.Array:
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    m = a.shape[-1]
    mask = jnp.uint32((1 << digit_bits) - 1)
    bits = jnp.uint32(digit_bits)

    a_pad = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, m)])    # (..., 2m)

    def step(carry, bj):
        acc, j = carry
        prod = a_pad * bj[..., None]          # digits j..j+m-1 of row j
        lo = prod & mask
        hi = prod >> bits
        hi = jnp.concatenate(
            [jnp.zeros(hi.shape[:-1] + (1,), U32), hi[..., :-1]], axis=-1)
        row = lo + hi                          # lazy, < 2**17
        row = jnp.roll(row, j, axis=-1)        # align to column j
        return (acc + row, j + 1), None

    b_t = jnp.moveaxis(b, -1, 0)
    acc0 = jnp.zeros(a_pad.shape, U32)
    (acc, _), _ = jax.lax.scan(step, (acc0, jnp.uint32(0)), b_t)
    # paper: "store & normalize" is the sequential drain.
    return normalize_digits_scan(acc, digit_bits)


# ---------------------------------------------------------------------------
# Digit-domain helpers for Karatsuba (lazy uint32 digit arithmetic).
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, n: int) -> jax.Array:
    m = x.shape[-1]
    if m == n:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n - m)])


def digit_sub_abs(x: jax.Array, y: jax.Array,
                  digit_bits: int = DIGIT_BITS) -> Tuple[jax.Array, jax.Array]:
    """|x - y| on equal-length normalized digit arrays, plus sign.

    Returns (|x - y| normalized, neg) with neg = 1 where x < y.
    Uses radix-complement addition: x - y + B**n = x + ~y + 1; the carry out
    of the top digit is 1 iff x >= y.
    """
    n = x.shape[-1]
    mask = jnp.uint32((1 << digit_bits) - 1)
    comp = (mask - y) & mask
    s = x + comp                                # lazy, < 2**17
    one = jnp.zeros(x.shape[:-1] + (n + 1,), U32).at[..., 0].set(1)
    s = _pad_to(s, n + 1) + one
    s = normalize_digits(s, digit_bits)
    ge = s[..., -1]                             # carry out: 1 iff x >= y
    d_pos = s[..., :-1]                         # x - y      (valid when ge)
    # if x < y: result held x - y + B**n; |x - y| = B**n - that = complement+1
    comp_d = (mask - d_pos) & mask
    d_neg = normalize_digits(
        _pad_to(comp_d, n + 1) + one, digit_bits)[..., :-1]
    neg = (ge == 0).astype(U32)
    out = jnp.where(neg[..., None] == 1, d_neg, d_pos)
    return out, neg


def mul_karatsuba(a: jax.Array, b: jax.Array, threshold: int = 16,
                  digit_bits: int = DIGIT_BITS,
                  base=dot_mul) -> jax.Array:
    """Karatsuba over normalized digit arrays with a DoT base case.

    Mirrors paper Algorithm 4 + the DoTMP integration: the recursion's
    add/sub work runs in the lazy digit domain (deferred carries), and the
    base case is DoT multiplication.  Returns (..., 2m) normalized digits.
    """
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    m = a.shape[-1]
    assert b.shape[-1] == m
    if m <= threshold:
        return base(a, b) if base is not dot_mul else dot_mul(a, b, digit_bits)
    if m % 2:
        a, b = _pad_to(a, m + 1), _pad_to(b, m + 1)
        return mul_karatsuba(a, b, threshold, digit_bits, base)[..., : 2 * m]
    k = m // 2
    a_l, a_h = a[..., :k], a[..., k:]
    b_l, b_h = b[..., :k], b[..., k:]

    p0 = mul_karatsuba(a_l, b_l, threshold, digit_bits, base)   # (..., 2k)
    p1 = mul_karatsuba(a_h, b_h, threshold, digit_bits, base)   # (..., 2k)
    da, sa = digit_sub_abs(a_h, a_l, digit_bits)
    db, sb = digit_sub_abs(b_h, b_l, digit_bits)
    pd = mul_karatsuba(da, db, threshold, digit_bits, base)     # (..., 2k)

    # middle = p1 + p0 -/+ pd  (sign = sa XOR sb); always >= 0.
    neg = (sa ^ sb).astype(U32)
    s01 = p0 + p1                                               # lazy < 2**17
    n = 2 * k
    mask = jnp.uint32((1 << digit_bits) - 1)
    # mid_minus = s01 - pd via radix complement: s01 + ~pd + 1 = mid + B**n.
    # 0 <= mid < 2*B**n, so after normalization the top digit is 1 + the
    # overflow digit of mid; subtracting 1 never borrows.
    comp = (mask - pd) & mask
    tot = _pad_to(s01 + comp, n + 1).at[..., 0].add(1)
    tot = normalize_digits(tot, digit_bits)
    mid_minus = tot.at[..., -1].set(tot[..., -1] - 1)
    mid_plus = normalize_digits(_pad_to(s01 + pd, n + 1), digit_bits)
    mid = jnp.where(neg[..., None] == 1, mid_plus, mid_minus)   # (..., 2k+1)

    out = jnp.zeros(a.shape[:-1] + (2 * m,), U32)
    out = out.at[..., : 2 * k].add(p0)
    out = out.at[..., k: k + 2 * k + 1].add(mid)
    out = out.at[..., 2 * k:].add(p1)
    return normalize_digits(out, digit_bits)


# ---------------------------------------------------------------------------
# 32-bit limb entry points (the GMP/OpenSSL-facing API of sec 3.3: accept
# the saturated radix used by the host library, convert, multiply, convert
# back --- the "radix conversion packing at entry / unpacking at exit").
#
# The unified pipeline front door: ``method="auto"`` routes through
# ``select_method`` (size-based dispatch over the jnp compositions AND the
# Pallas kernel family -- VPU-VnC, MXU Toeplitz, fused Karatsuba).
# ---------------------------------------------------------------------------

MUL_METHODS = ("dot", "mxu", "schoolbook", "karatsuba",
               "pallas", "pallas_mxu", "pallas_kara", "ntt")


def select_method(nbits: int, batch: int = 1,
                  prefer_mxu: bool = False) -> str:
    """Size-based multiply dispatch (see configs/dot_bignum.MUL_DISPATCH).

    * tiny operands: the jnp VnC composition ("dot"); a kernel launch
      costs more than it saves,
    * up to one base case (512 bits): the single-launch Pallas VnC
      kernel ("pallas"),
    * 512..4096 bits: the fused Karatsuba kernel ("pallas_kara"),
    * beyond the fused kernel's overflow analysis: the jnp Karatsuba
      composition ("karatsuba"),
    * huge operands (>= ``cfg.ntt_min_bits``): the fused NTT/CRT kernel
      family ("ntt") -- O(n log n) butterflies, one launch per CRT prime
      (kernels/ntt_mul).

    ``prefer_mxu`` selects the int8 Toeplitz kernel where its range
    allows (worth it when the MXU would otherwise sit idle).  A
    ``repro.api.configure(mul_method=...)`` override wins over
    everything (ops knob for A/B experiments without code changes); the
    REPRO_MUL_BACKEND env var is its deprecated alias.

    Batch awareness: the kernels tile the BATCH axis -- that is where
    the carry machinery amortizes.  Below ``cfg.kernel_min_batch``
    independent operations a launch cannot pay for itself (and on CPU
    its interpret-mode compile dwarfs the work), so small batches take
    the jnp compositions while the quadratic VnC outer product stays
    small.  The NTT tier is the exception: above the small-batch dot
    range it runs even at batch 1, because its trace is O(log n) stages
    (a batch-1 launch compiles in seconds, where the jnp Karatsuba
    composition's compile takes minutes past 4096 bits) and its
    O(n log n) work beats the composition outright.  The division
    subsystem's batch-1 paths (base conversion, the pi workload) live
    in this regime -- their huge-width multiplies ride the NTT tier
    automatically.
    """
    from repro import config as _rc
    from repro.configs.dot_bignum import MUL_DISPATCH as cfg
    from repro.obs import trace as _trace

    override = _rc.resolve("mul_method", MUL_METHODS, "multiply method")
    if override:
        choice, rule, detail = override, "override", {}
    elif batch < cfg.kernel_min_batch:
        if nbits <= cfg.small_batch_dot_max_bits:
            choice, rule = "dot", "small_batch_dot_max_bits"
            detail = {"threshold": cfg.small_batch_dot_max_bits}
        else:
            choice, rule = "ntt", "small_batch_ntt"
            detail = {"threshold": cfg.small_batch_dot_max_bits}
    elif prefer_mxu and nbits <= cfg.mxu_max_bits:
        choice, rule = "pallas_mxu", "prefer_mxu"
        detail = {"threshold": cfg.mxu_max_bits}
    elif nbits <= cfg.jnp_max_bits:
        choice, rule = "dot", "jnp_max_bits"
        detail = {"threshold": cfg.jnp_max_bits}
    elif nbits <= cfg.vnc_max_bits:
        choice, rule = "pallas", "vnc_max_bits"
        detail = {"threshold": cfg.vnc_max_bits}
    elif nbits <= cfg.fused_kara_max_bits:
        choice, rule = "pallas_kara", "fused_kara_max_bits"
        detail = {"threshold": cfg.fused_kara_max_bits}
    elif nbits < cfg.ntt_min_bits:
        choice, rule = "karatsuba", "below_ntt_min_bits"
        detail = {"threshold": cfg.ntt_min_bits}
    else:
        choice, rule = "ntt", "ntt_min_bits"
        detail = {"threshold": cfg.ntt_min_bits}
    _trace.emit("mul", nbits, batch, choice, rule, **detail)
    return choice


def _flatten_leading(x: jax.Array):
    return x.reshape((-1, x.shape[-1])), x.shape[:-1]


def mul_limbs32(a_limbs: jax.Array, b_limbs: jax.Array,
                method: str = "auto",
                b_const: int | None = None) -> jax.Array:
    """(..., m) uint32 limbs x2 -> (..., 2m) uint32 limbs (full product).

    ``b_const``, when given, asserts that b_limbs holds the HOST-KNOWN
    value b_const in every lane; the NTT tier then multiplies against
    the prepared-operand cache (one forward transform per launch instead
    of two -- kernels/ntt_mul.prepared_operand).  Other methods ignore
    it, so callers can pass it unconditionally for any fixed operand.
    """
    m = a_limbs.shape[-1]
    if method == "auto":
        batch = 1
        for d in a_limbs.shape[:-1]:
            batch *= int(d)
        method = select_method(32 * m, batch=batch)
    if method in ("pallas", "pallas_mxu", "pallas_kara", "ntt"):
        # kernel entry points are 2-D (batch, m); imported lazily because
        # the ops modules import core.mul at module level (cycle) -- core
        # depends statically only on the pure-jnp kernels/common helpers
        from repro.resilience import guard as _guard

        a2, lead = _flatten_leading(jnp.asarray(a_limbs, U32))
        b2, _ = _flatten_leading(jnp.asarray(b_limbs, U32))

        def _kernel_tier():
            if method == "pallas":
                from repro.kernels.dot_mul import ops as _k
                return _k.dot_mul_limbs32(a2, b2)
            if method == "pallas_mxu":
                from repro.kernels.mxu_mul import ops as _k
                return _k.mxu_mul_limbs32(a2, b2)
            if method == "ntt":
                from repro.kernels.ntt_mul import ops as _k
                if b_const is not None and _k.operand_cache_capacity() > 0:
                    return _k.ntt_mul_limbs32_prepared(a2, b_const)
                return _k.ntt_mul_limbs32(a2, b2)
            from repro.kernels.kara_mul import ops as _k
            return _k.kara_mul_limbs32(a2, b2)

        # the jnp fallback mirrors the kernel's algorithmic family: the
        # single-launch VnC / Toeplitz kernels degrade to the jnp VnC
        # composition, the fused Karatsuba / NTT tiers to jnp Karatsuba
        # (quadratic "dot" at those widths would be the real outage)
        fb = "dot" if method in ("pallas", "pallas_mxu") else "karatsuba"

        def _reference_tier():
            def _host(a_np, b_np):
                from repro.core import limbs as _L
                prods = [x * y for x, y in
                         zip(_L.batch_to_ints(np.asarray(a_np)),
                             _L.batch_to_ints(np.asarray(b_np)))]
                return _L.ints_to_batch(prods, 2 * m)
            shape = jax.ShapeDtypeStruct((a2.shape[0], 2 * m), np.uint32)
            return jax.pure_callback(_host, shape, a2, b2, vmap_method="sequential")

        out = _guard.run("mul", 32 * m, [
            (method, _kernel_tier),
            (fb, lambda: mul_limbs32(a2, b2, method=fb)),
            ("reference", _reference_tier),
        ])
        return out.reshape(lead + (2 * m,))
    a_d = split_digits(a_limbs, DIGIT_BITS)
    b_d = split_digits(b_limbs, DIGIT_BITS)
    if method == "dot":
        p = dot_mul(a_d, b_d)
    elif method == "mxu":
        a7 = split_digits(a_limbs, MXU_DIGIT_BITS)
        b7 = split_digits(b_limbs, MXU_DIGIT_BITS)
        p7 = dot_mul_mxu(a7, b7)
        return join_digits(p7, MXU_DIGIT_BITS, 2 * m)
    elif method == "schoolbook":
        p = mul_schoolbook(a_d, b_d)
    elif method == "karatsuba":
        p = mul_karatsuba(a_d, b_d)
    else:
        raise ValueError(
            f"unknown multiply method {method!r}; choose from "
            f"{('auto',) + MUL_METHODS} (REPRO_MUL_BACKEND accepts the "
            f"same names, minus 'auto')")
    return join_digits(p, DIGIT_BITS, 2 * m)


@functools.partial(jax.jit, static_argnames=("method",))
def mul_jit(a_limbs: jax.Array, b_limbs: jax.Array, method: str = "auto"):
    return mul_limbs32(a_limbs, b_limbs, method)
