"""Batched binary GCD on DoT digit arithmetic (GMPbench's gcd aggregate).

The paper's Fig. 4 shows GCD improving +3.1% purely because GMP's
Lehmer-Euclid bottoms out in large add/sub -- the cascade effect.  Here
the whole algorithm is built from DoT primitives: digit-wise compare,
radix-complement subtraction with deferred carries, and vectorized
shifts, batched over lanes (every branch of the classic binary GCD
becomes a masked select, so thousands of GCDs advance per vector step).

Iteration bound: each step strictly reduces bitlen(u)+bitlen(v) by >= 1,
so 2*nbits steps suffice; the while_loop exits as soon as every lane's v
reaches zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32
DIGIT_BITS = 16
MASK = jnp.uint32(0xFFFF)


def _is_even(x):
    return (x[..., 0] & jnp.uint32(1)) == 0


def _is_zero(x):
    return jnp.all(x == 0, axis=-1)


def _shr1(x):
    """x >> 1 across digits (little-endian)."""
    hi = jnp.concatenate(
        [x[..., 1:], jnp.zeros(x.shape[:-1] + (1,), U32)], axis=-1)
    return (x >> jnp.uint32(1)) | ((hi & jnp.uint32(1)) << jnp.uint32(15))


def _shl1(x):
    """x << 1 across digits (mod B**m)."""
    lo = jnp.concatenate(
        [jnp.zeros(x.shape[:-1] + (1,), U32), x[..., :-1]], axis=-1)
    return ((x << jnp.uint32(1)) & MASK) | (lo >> jnp.uint32(15))


def _ge(a, b):
    """a >= b, digit arrays, lexicographic from the top (vector scan)."""
    gt = (a > b).astype(jnp.int32)
    lt = (a < b).astype(jnp.int32)
    diff = gt - lt

    def step(carry, d):
        return jnp.where(d != 0, d, carry), None

    d_t = jnp.moveaxis(diff, -1, 0)
    out, _ = jax.lax.scan(step, jnp.zeros(a.shape[:-1], jnp.int32), d_t)
    return out >= 0


def _sub(a, b):
    """a - b (a >= b), radix complement + deferred-carry resolve."""
    from repro.core.mul import normalize_digits
    comp = (MASK - b) & MASK
    t = (a + comp).at[..., 0].add(1)
    return normalize_digits(t, DIGIT_BITS)


def gcd(u: jax.Array, v: jax.Array) -> jax.Array:
    """Batched gcd of (..., m) radix-2**16 digit arrays."""
    u = jnp.asarray(u, U32)
    v = jnp.asarray(v, U32)
    m = u.shape[-1]
    shift = jnp.zeros(u.shape[:-1], U32)

    def cond(state):
        u, v, shift = state
        return jnp.any(~_is_zero(v))

    def body(state):
        u, v, shift = state
        active = ~_is_zero(v)
        uz = _is_zero(u) & active          # gcd(0, v) = v: move v into u
        ue, ve = _is_even(u), _is_even(v)
        act = active & ~uz
        both = act & ue & ve
        only_u = act & ue & ~ve
        only_v = act & ~ue & ve
        odd = act & ~ue & ~ve
        uge = _ge(u, v)

        u_new = jnp.where(both[..., None] | only_u[..., None], _shr1(u), u)
        v_new = jnp.where(both[..., None] | only_v[..., None], _shr1(v), v)
        # both odd: subtract the smaller from the larger, then halve
        du = _shr1(_sub(u, v))     # valid where u >= v
        dv = _shr1(_sub(v, u))     # valid where v >  u
        u_new = jnp.where((odd & uge)[..., None], du, u_new)
        v_new = jnp.where((odd & ~uge)[..., None], dv, v_new)
        # u == 0 lane: u <- v, v <- 0 (terminates the lane next check)
        u_new = jnp.where(uz[..., None], v, u_new)
        v_new = jnp.where(uz[..., None], jnp.zeros_like(v), v_new)
        shift = shift + both.astype(U32)
        return u_new, v_new, shift

    u, v, shift = jax.lax.while_loop(cond, body, (u, v, shift))

    # result = u << shift  (per-lane shift count; repeated doubling)
    def cond2(state):
        u, shift = state
        return jnp.any(shift > 0)

    def body2(state):
        u, shift = state
        doit = shift > 0
        u = jnp.where(doit[..., None], _shl1(u), u)
        return u, shift - doit.astype(U32)

    u, _ = jax.lax.while_loop(cond2, body2, (u, shift))
    return u
