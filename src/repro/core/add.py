"""DoT addition/subtraction (paper Algorithm 1) and prior-work baselines.

All routines operate on batched little-endian uint32 limb arrays
``(..., m)`` and return ``(sum_limbs, carry_out)`` where ``carry_out`` has
shape ``(...)`` (uint32, 0 or 1).  Batching is the TPU analogue of issuing
many independent SIMD adds: the VPU vectorizes over BOTH the limb axis and
the batch axis, and the dominant carry-management cost is amortized exactly
the way the paper's Phase 2/3 amortize it over AVX-512 lanes.

Hardware adaptation (see DESIGN.md):
  * AVX-512 ``simd_cmp_lt`` mask        -> jnp compare on uint32 vregs.
  * cross-lane mask shift (P2)          -> limb-axis roll (static slice
                                           concat; lowers to cheap
                                           lane-shift on the VPU).
  * scalar slow path (P4)               -> ``lax.cond`` whose rare branch
                                           resolves carries with a
                                           Kogge-Stone ``associative_scan``
                                           (the paper's P4 cites the same
                                           KSA adjustment trick).

Implemented strategies (paper sec 2.2/2.3 baselines + DoT):
  add_seq          - GMP-style ADC chain (Algorithm 3): lax.scan over limbs.
  add_naive_simd   - P1 vector add, then m-step sequential carry ripple
                     ("Naive SIMD" column of Table 1).
  add_ksa          - full Kogge-Stone carry-lookahead via associative_scan
                     (log-depth; always-correct reference vector path).
  add_two_level    - y-cruncher-style two-level KSA (Table 1, col 3).
  add_carry_select - Ren et al.-style block carry-select (Table 1, col 2).
  dot_add          - the paper's 4-phase algorithm (Algorithm 1).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

U32 = jnp.uint32
_MAX = jnp.uint32(0xFFFFFFFF)
_ONE = jnp.uint32(1)
_ZERO = jnp.uint32(0)

Pair = Tuple[jax.Array, jax.Array]


def _as_u32(x):
    return jnp.asarray(x, U32)


def _cin_array(a: jax.Array, carry_in) -> jax.Array:
    """Broadcast carry_in to the batch shape (...,)."""
    if carry_in is None:
        carry_in = 0
    cin = jnp.asarray(carry_in, U32)
    return jnp.broadcast_to(cin, a.shape[:-1])


def _shift_up(c: jax.Array, cin: jax.Array) -> jax.Array:
    """Move per-limb flags one position toward the MSB; insert cin at limb 0.

    This is the paper's Phase-2 ``(c << 1) | c_in`` on the carry mask,
    expressed on the limb axis.
    """
    return jnp.concatenate([cin[..., None], c[..., :-1]], axis=-1)


# ---------------------------------------------------------------------------
# Kogge-Stone carry resolution (generate/propagate semiring scan).
# ---------------------------------------------------------------------------

def _gp_combine(lo: Pair, hi: Pair) -> Pair:
    """Associative combine for (generate, propagate); lo is less significant."""
    g_lo, p_lo = lo
    g_hi, p_hi = hi
    return g_hi | (p_hi & g_lo), p_hi & p_lo


def _carries_ksa(g: jax.Array, p: jax.Array, cin: jax.Array) -> Pair:
    """Exact carries into each limb + carry out, via log-depth scan.

    g, p: (..., m) uint32 {0,1}: per-limb generate/propagate.
    Returns (c, cout): c[..., i] = carry INTO limb i.
    """
    G, P = jax.lax.associative_scan(_gp_combine, (g, p), axis=-1)
    # carry into limb i is the carry OUT of prefix [0, i): shift up by one.
    cout = G[..., -1] | (P[..., -1] & cin)
    c = _shift_up(G | (P & cin[..., None]), cin)
    return c, cout


# ---------------------------------------------------------------------------
# DoT addition: Algorithm 1 (4 phases).
# ---------------------------------------------------------------------------

def dot_add(a: jax.Array, b: jax.Array, carry_in=None) -> Pair:
    """Paper Algorithm 1.  (..., m) uint32 -> ((..., m) sum, (...) carry_out).

    Phases 1-3 are branch-free vector code; Phase 4 (cascading carries,
    probability ~2**-32 per limb for random inputs, Appendix B) runs under a
    ``lax.cond`` and resolves the cascade with a Kogge-Stone scan.
    """
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)

    # Phase 1: limb-wise parallel add (no carry management).
    r = a + b
    # Phase 2: carry detection (r < a <=> overflow), align one limb up,
    # extract the top-limb carry as carry_out.
    c = (r < a).astype(U32)
    cout = c[..., -1]
    c_aligned = _shift_up(c, cin)
    # Phase 3: single parallel carry addition.
    r2 = r + c_aligned
    overflow2 = (r2 < r).astype(U32)  # only possible where r == MAX, c == 1

    # carry straight out of the top limb during P3 is NOT a cascade:
    cout_fast = cout | overflow2[..., -1]
    cascade = jnp.any(overflow2[..., :-1] != 0)

    def fast(_):
        return r2, cout_fast

    def slow(_):
        # Phase 4: rare cascading-carry case.  Resolve exactly with the
        # Kogge-Stone generate/propagate scan (the paper's P4 adjustment is
        # the KSA trick; the scan is its general log-depth form).
        g = (r < a).astype(U32)           # limb generated a carry in P1
        p = (r == _MAX).astype(U32)       # limb propagates an incoming carry
        cfull, cout_s = _carries_ksa(g, p, cin)
        return r + cfull, cout_s

    return jax.lax.cond(cascade, slow, fast, operand=None)


def dot_add_unconditional(a: jax.Array, b: jax.Array, carry_in=None) -> Pair:
    """DoT phases 1-3 with a branch-free KSA Phase 4 (no lax.cond).

    Inside Pallas kernels and under vmap it is often cheaper on TPU to run
    the (vectorized, log-depth) adjustment unconditionally than to branch;
    this variant is the kernel oracle and the in-kernel schedule.
    """
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)
    r = a + b
    g = (r < a).astype(U32)
    p = (r == _MAX).astype(U32)
    c, cout = _carries_ksa(g, p, cin)
    return r + c, cout


# ---------------------------------------------------------------------------
# DoT subtraction (borrows mirror carries; paper sec 3.1 "Subtraction").
# ---------------------------------------------------------------------------

def dot_sub(a: jax.Array, b: jax.Array, borrow_in=None) -> Pair:
    """(..., m) - (..., m) -> (difference mod 2**(32m), borrow_out)."""
    a, b = _as_u32(a), _as_u32(b)
    bin_ = _cin_array(a, borrow_in)

    # Phase 1: limb-wise subtract.
    r = a - b
    # Phase 2: borrow detection + alignment.
    br = (a < b).astype(U32)
    bout = br[..., -1]
    b_aligned = _shift_up(br, bin_)
    # Phase 3: subtract aligned borrows.
    r2 = r - b_aligned
    under2 = (r2 > r).astype(U32)  # only possible where r == 0, borrow == 1

    bout_fast = bout | under2[..., -1]
    cascade = jnp.any(under2[..., :-1] != 0)

    def fast(_):
        return r2, bout_fast

    def slow(_):
        g = (a < b).astype(U32)       # limb generates a borrow
        p = (r == _ZERO).astype(U32)  # limb propagates an incoming borrow
        bfull, bout_s = _carries_ksa(g, p, bin_)
        return r - bfull, bout_s

    return jax.lax.cond(cascade, slow, fast, operand=None)


def dot_sub_unconditional(a: jax.Array, b: jax.Array, borrow_in=None) -> Pair:
    a, b = _as_u32(a), _as_u32(b)
    bin_ = _cin_array(a, borrow_in)
    r = a - b
    g = (a < b).astype(U32)
    p = (r == _ZERO).astype(U32)
    bfull, bout = _carries_ksa(g, p, bin_)
    return r - bfull, bout


# ---------------------------------------------------------------------------
# Baselines (paper Table 1): each reproduces a prior approach's dependency
# structure so the benchmark harness can reproduce the paper's comparisons.
# ---------------------------------------------------------------------------

def add_seq(a: jax.Array, b: jax.Array, carry_in=None) -> Pair:
    """GMP-style sequential ADC chain (paper Algorithm 3): O(m) depth."""
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)

    def step(c, ab):
        ai, bi = ab
        s = ai + bi
        c1 = (s < ai).astype(U32)
        s2 = s + c
        c2 = (s2 < s).astype(U32)
        return c1 | c2, s2

    # scan over the limb axis (moved to axis 0).
    a_t = jnp.moveaxis(a, -1, 0)
    b_t = jnp.moveaxis(b, -1, 0)
    cout, s_t = jax.lax.scan(step, cin, (a_t, b_t))
    return jnp.moveaxis(s_t, 0, -1), cout


def sub_seq(a: jax.Array, b: jax.Array, borrow_in=None) -> Pair:
    """Sequential SBB chain."""
    a, b = _as_u32(a), _as_u32(b)
    bin_ = _cin_array(a, borrow_in)

    def step(br, ab):
        ai, bi = ab
        d = ai - bi
        b1 = (ai < bi).astype(U32)
        d2 = d - br
        b2 = (d2 > d).astype(U32)
        return b1 | b2, d2

    a_t = jnp.moveaxis(a, -1, 0)
    b_t = jnp.moveaxis(b, -1, 0)
    bout, d_t = jax.lax.scan(step, bin_, (a_t, b_t))
    return jnp.moveaxis(d_t, 0, -1), bout


def add_naive_simd(a: jax.Array, b: jax.Array, carry_in=None) -> Pair:
    """"Naive SIMD" (Table 1, col 1): vector add + sequential carry ripple.

    After the parallel P1 add, carries are propagated one limb per iteration
    for m-1 iterations -- the software reconstruction of the hardware carry
    chain that the paper measures at a 52.1 carry-to-add ratio.
    """
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)
    m = a.shape[-1]

    r = a + b
    c = (r < a).astype(U32)
    cout = jnp.zeros_like(cin)

    def body(_, state):
        r, c, cout = state
        cout = cout | c[..., -1]
        c_sh = _shift_up(c, jnp.zeros_like(cout))
        r2 = r + c_sh
        c2 = (r2 < r).astype(U32)
        return r2, c2, cout

    # first ripple consumes cin as well
    c0 = _shift_up(c, cin)
    cout = c[..., -1]
    r = r + c0
    c = (r < (r - c0)).astype(U32)
    r, c, cout = jax.lax.fori_loop(0, m, body, (r, c, cout))
    return r, cout


def add_ksa(a: jax.Array, b: jax.Array, carry_in=None) -> Pair:
    """Full Kogge-Stone carry-lookahead addition (log-depth, branch-free)."""
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)
    r = a + b
    g = (r < a).astype(U32)
    p = (r == _MAX).astype(U32)
    c, cout = _carries_ksa(g, p, cin)
    return r + c, cout


def add_two_level(a: jax.Array, b: jax.Array, carry_in=None,
                  block: int = 8) -> Pair:
    """Two-level Kogge-Stone (y-cruncher / Yee [82], Table 1 col 3).

    Level 1 resolves carries within w-limb blocks independently; level 2
    scans block-level (G, P) pairs and re-applies the block carry-in.
    """
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)
    m = a.shape[-1]
    pad = (-m) % block
    if pad:
        zeros = jnp.zeros(a.shape[:-1] + (pad,), U32)
        a = jnp.concatenate([a, zeros], axis=-1)
        b = jnp.concatenate([b, zeros], axis=-1)
    mt = a.shape[-1]
    nb = mt // block
    shp = a.shape[:-1] + (nb, block)
    ab, bb = a.reshape(shp), b.reshape(shp)

    r = ab + bb
    g = (r < ab).astype(U32)
    p = (r == _MAX).astype(U32)
    # level 1: prefix scan within blocks.
    G1, P1 = jax.lax.associative_scan(_gp_combine, (g, p), axis=-1)
    gB, pB = G1[..., -1], P1[..., -1]          # block-level generate/propagate
    # level 2: prefix scan across blocks.
    G2, P2 = jax.lax.associative_scan(_gp_combine, (gB, pB), axis=-1)
    cout = G2[..., -1] | (P2[..., -1] & cin)
    blk_cin = _shift_up(G2 | (P2 & cin[..., None]), cin)   # (..., nb)
    # carries into each limb: from within-block prefix + block carry-in.
    c_in_limb = _shift_up(
        (G1 | (P1 & blk_cin[..., None])).reshape(a.shape), cin)
    s = (ab + bb).reshape(a.shape) + c_in_limb
    if pad:
        # the carry out of limb m-1 landed in the first padded (zero) limb.
        cout = s[..., m]
        s = s[..., :m]
    return s, cout


def add_carry_select(a: jax.Array, b: jax.Array, carry_in=None,
                     block: int = 8) -> Pair:
    """Ren et al.-style carry-select blocks (Table 1 col 2).

    Each block computes BOTH outcomes (carry-in 0 and 1); a sequential
    scan over blocks then selects.  Reproduces the "compute twice, select"
    structure whose preparation overhead the paper measures at 12.4x.
    """
    a, b = _as_u32(a), _as_u32(b)
    cin = _cin_array(a, carry_in)
    m = a.shape[-1]
    pad = (-m) % block
    if pad:
        zeros = jnp.zeros(a.shape[:-1] + (pad,), U32)
        a = jnp.concatenate([a, zeros], axis=-1)
        b = jnp.concatenate([b, zeros], axis=-1)
    nb = a.shape[-1] // block
    shp = a.shape[:-1] + (nb, block)
    ab, bb = a.reshape(shp), b.reshape(shp)

    r = ab + bb
    g = (r < ab).astype(U32)
    p = (r == _MAX).astype(U32)
    G1, P1 = jax.lax.associative_scan(_gp_combine, (g, p), axis=-1)
    zero = jnp.zeros(ab.shape[:-1], U32)
    one = jnp.ones(ab.shape[:-1], U32)
    # both versions of every block:
    c0 = _shift_up(G1, zero)
    c1 = _shift_up(G1 | P1, one)
    s0 = r + c0
    s1 = r + c1
    cout0 = G1[..., -1]
    cout1 = (G1 | P1)[..., -1]

    # sequential select over blocks (the carry-select chain).
    def step(c, xs):
        s0_b, s1_b, c0_b, c1_b = xs
        s = jnp.where((c == 1)[..., None], s1_b, s0_b)
        cn = jnp.where(c == 1, c1_b, c0_b)
        return cn, s

    xs = (jnp.moveaxis(s0, -2, 0), jnp.moveaxis(s1, -2, 0),
          jnp.moveaxis(cout0, -1, 0), jnp.moveaxis(cout1, -1, 0))
    cout, s_t = jax.lax.scan(step, cin, xs)
    s = jnp.moveaxis(s_t, 0, -2).reshape(a.shape)
    if pad:
        # the carry out of limb m-1 landed in the first padded (zero) limb.
        cout = s[..., m]
        s = s[..., :m]
    return s, cout


ADD_STRATEGIES = {
    "dot": dot_add,
    "dot_uncond": dot_add_unconditional,
    "seq": add_seq,
    "naive_simd": add_naive_simd,
    "ksa": add_ksa,
    "two_level_ksa": add_two_level,
    "carry_select": add_carry_select,
}

SUB_STRATEGIES = {
    "dot": dot_sub,
    "dot_uncond": dot_sub_unconditional,
    "seq": sub_seq,
}


@functools.partial(jax.jit, static_argnames=("strategy",))
def add_jit(a: jax.Array, b: jax.Array, strategy: str = "dot") -> Pair:
    return ADD_STRATEGIES[strategy](a, b)


@functools.partial(jax.jit, static_argnames=("strategy",))
def sub_jit(a: jax.Array, b: jax.Array, strategy: str = "dot") -> Pair:
    return SUB_STRATEGIES[strategy](a, b)
