"""Exact, order-invariant gradient accumulation via deferred-carry limbs.

This is the paper's central insight applied to distributed training:
DoT defers carry propagation so the data-parallel work (limb adds) runs
carry-free, with a single resolution pass at the end.  Here the "lanes"
are gradient elements and the "adds" are cross-replica reductions:

  1. quantize each f32 gradient to a fixed-point int (deterministic),
  2. split into L unsaturated radix-2**r digits (headroom = 32 - r bits),
  3. psum the digit planes across replicas -- integer adds are exactly
     associative AND commutative, so the result is bitwise identical for
     ANY reduction order, replica count, or mesh shape (elastic rescaling
     keeps bit-exact training curves),
  4. resolve carries ONCE (DoT-style deferred passes + Kogge-Stone tail),
  5. convert back to f32.

With r = 20 and L = 4 the accumulator spans 80 bits: up to 2**(31-20) =
2048 addends sum with NO intermediate carry handling at all (phase-2/3 of
the paper never even run until the end).  Plain f32 psum is neither
order- nor topology-invariant; bf16 compression is worse.  See
tests/test_exact_accum.py for the bitwise-invariance property tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

U32 = jnp.uint32
I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ExactAccumConfig:
    frac_bits: int = 24          # fixed-point resolution: 2**-24 absolute
    radix_bits: int = 20         # digit width; headroom = 32 - radix_bits
    num_limbs: int = 4           # accumulator range: radix_bits * num_limbs
    clip: float = 64.0           # |values| clipped to keep q in int32

    @property
    def headroom_addends(self) -> int:
        """How many addends can accumulate with zero carry handling."""
        return 1 << (31 - self.radix_bits)

    @property
    def total_bits(self) -> int:
        return self.radix_bits * self.num_limbs


DEFAULT = ExactAccumConfig()


def encode(x: jax.Array, cfg: ExactAccumConfig = DEFAULT) -> jax.Array:
    """f32 (...,) -> uint32 (..., L) two's-complement digit planes."""
    q = jnp.round(jnp.clip(x.astype(F32), -cfg.clip, cfg.clip)
                  * (2.0 ** cfg.frac_bits)).astype(I32)
    u = q.astype(U32)  # two's complement bits
    r = cfg.radix_bits
    mask = jnp.uint32((1 << r) - 1)
    digits = []
    neg_fill = jnp.where(q < 0, mask, jnp.uint32(0))
    for k in range(cfg.num_limbs):
        lo_bit = r * k
        if lo_bit < 32:
            d = (u >> jnp.uint32(lo_bit))
            if lo_bit + r > 32:
                # splice in sign-extension bits above bit 31
                ext_bits = lo_bit + r - 32
                ext = jnp.where(q < 0, jnp.uint32((1 << ext_bits) - 1),
                                jnp.uint32(0))
                d = d | (ext << jnp.uint32(32 - lo_bit))
            digits.append(d & mask)
        else:
            digits.append(neg_fill)
    return jnp.stack(digits, axis=-1)


def accumulate(acc: jax.Array, digits: jax.Array) -> jax.Array:
    """Deferred-carry add: plain elementwise uint32 adds, NO carry work.

    Safe for up to cfg.headroom_addends accumulations between normalize()
    calls (the caller asserts this budget; see train/trainer.py).
    """
    return acc + digits


def normalize(acc: jax.Array, cfg: ExactAccumConfig = DEFAULT) -> jax.Array:
    """Resolve deferred carries mod 2**(r*L): two DoT passes + KS tail.

    After accumulation each digit holds < 2**31; two deferred passes bring
    every digit to <= 2**r, and a Kogge-Stone generate/propagate pass
    resolves the remaining 0/1 carries exactly (branch-free; this is the
    same Phase-4 structure as DoT addition).
    """
    r = jnp.uint32(cfg.radix_bits)
    mask = jnp.uint32((1 << cfg.radix_bits) - 1)

    def shift_up(c):
        return jnp.concatenate(
            [jnp.zeros(c.shape[:-1] + (1,), U32), c[..., :-1]], axis=-1)

    # two deferred-carry passes (digit <= 2**r afterwards)
    for _ in range(2):
        acc = (acc & mask) + shift_up(acc >> r)
    # Kogge-Stone tail on the residual 0/1 carries
    g = (acc >> r).astype(U32)           # digit generated (value == 2**r)
    low = acc & mask
    p = (low == mask).astype(U32)

    def combine(lo, hi):
        g1, p1 = lo
        g2, p2 = hi
        return g2 | (p2 & g1), p2 & p1

    G, P = jax.lax.associative_scan(combine, (g, p), axis=-1)
    c = shift_up(G)
    return (low + c) & mask              # overflow beyond L limbs wraps (mod)


def _resolve_unit_carries(t: jax.Array, cfg: ExactAccumConfig) -> jax.Array:
    """Digits <= 2**r with 0/1 residual carries -> normalized (KS tail)."""
    r = jnp.uint32(cfg.radix_bits)
    mask = jnp.uint32((1 << cfg.radix_bits) - 1)
    g = (t >> r).astype(U32)
    low = t & mask
    p = (low == mask).astype(U32)

    def combine(lo, hi):
        g1, p1 = lo
        g2, p2 = hi
        return g2 | (p2 & g1), p2 & p1

    G, P = jax.lax.associative_scan(combine, (g, p), axis=-1)
    c = jnp.concatenate(
        [jnp.zeros(G.shape[:-1] + (1,), U32), G[..., :-1]], axis=-1)
    return (low + c) & mask


def decode(acc: jax.Array, cfg: ExactAccumConfig = DEFAULT) -> jax.Array:
    """Normalized digit planes -> f32 (two's complement interpretation).

    Negatives are complemented in the INTEGER domain first: converting
    2**(rL) - |v| to f32 and subtracting 2**(rL) would round |v| away
    entirely (ulp(2**80) >> any gradient sum)."""
    r = cfg.radix_bits
    mask = jnp.uint32((1 << r) - 1)
    # sign bit: top bit of the top digit
    neg = (acc[..., -1] >> jnp.uint32(r - 1)) & jnp.uint32(1)
    # |v| for negatives: complement + 1, carries resolved exactly
    comp = (mask - acc).at[..., 0].add(1)
    mag_neg = _resolve_unit_carries(comp, cfg)
    digits = jnp.where(neg[..., None] == 1, mag_neg, acc)
    val = jnp.zeros(acc.shape[:-1], F32)
    for k in reversed(range(cfg.num_limbs)):
        val = val * float(1 << r) + digits[..., k].astype(F32)
    val = jnp.where(neg == 1, -val, val)
    return val * (2.0 ** -cfg.frac_bits)


def exact_psum(digits: jax.Array, axis_name,
               cfg: ExactAccumConfig = DEFAULT) -> jax.Array:
    """Order-invariant cross-replica sum of encoded digit planes."""
    summed = jax.lax.psum(digits, axis_name)
    return normalize(summed, cfg)


# -- pytree convenience ------------------------------------------------------

def tree_encode(tree, cfg: ExactAccumConfig = DEFAULT):
    return jax.tree.map(lambda x: encode(x, cfg), tree)


def tree_decode(tree, cfg: ExactAccumConfig = DEFAULT):
    return jax.tree.map(lambda d: decode(normalize(d, cfg), cfg), tree)


def tree_accumulate(acc_tree, tree):
    return jax.tree.map(accumulate, acc_tree, tree)


def exact_reduce(x: jax.Array, n_chunks: int,
                 cfg: ExactAccumConfig = DEFAULT) -> jax.Array:
    """Single-host reference reduction: sum x over axis 0 exactly.

    Used by tests/benchmarks to demonstrate order invariance without a
    multi-device mesh: any permutation/regrouping of axis 0 produces a
    bitwise-identical result.
    """
    digits = encode(x, cfg)
    acc = digits.sum(axis=0, dtype=U32)     # associative integer adds
    return decode(normalize(acc, cfg), cfg)
