"""Big-number division on DoT digit arrays: Newton reciprocal, divmod,
constant-divisor division, and on-device base conversion.

The inverse operation the paper stops short of: add/sub/mul/modmul cover
the forward directions, but pi-style fixed-point series, RSA-CRT, and
any decimal output all need division.  Mathemagix-style Barrett reduction
(core/modular.py) and this module share one design rule: REDUCE DIVISION
TO MULTIPLICATION, because multiplication is the primitive the unified
pipeline (core/mul.select_method: jnp VnC / Pallas VnC / fused Karatsuba
/ MXU Toeplitz, autotuned tiles) already makes fast.  Division then
inherits every multiply backend for free.

Three division strategies, dispatched by ``select_div_method``:

  * ``small``      -- divisor is a host-side Python int < 2**digit_bits:
                      the classic MSB-first scalar scan (``div_small``),
                      one uint32 divide per digit.  The pi workload's
                      fast path.
  * ``schoolbook`` -- batched Knuth Algorithm D in a fused Pallas kernel
                      (kernels/dot_div): digit-serial trial quotients
                      with branch-free <=2-step add-back correction, the
                      whole partial remainder VMEM-resident.  Wins at
                      kernel-sized operands where a Newton iteration's
                      multiply chain costs more than m small steps.
  * ``recip``      -- Newton-Raphson fixed-point reciprocal
                      (``recip_digits``) + ONE full-width multiply for
                      the quotient + branch-free correction.  Every
                      Newton multiply routes through mul_limbs32's
                      ``auto`` dispatch, so large divisions ride the
                      fused Karatsuba kernel / jnp Karatsuba exactly
                      like large multiplies do (Kouya's branch-free
                      reciprocal structure, data-parallel over the
                      batch).

Correctness contract: quotient/remainder are EXACT (``q*b + r == a`` and
``0 <= r < b``) for every b >= 1; correction runs as masked while-loops
whose trip count is the (small, bounded) reciprocal error, so no error
analysis is load-bearing for exactness -- only for speed.  ``b == 0``
lanes are undefined (guarded so the correction loops still terminate).

Digit conventions match core/mul.py: little-endian, last axis, uint32
storage, normalized digits < 2**digit_bits unless noted.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core.mul import (DIGIT_BITS, join_digits, mul_limbs32,
                            normalize_digits, split_digits)

U32 = jnp.uint32

DIV_METHODS = ("schoolbook", "recip")


# ---------------------------------------------------------------------------
# Digit-domain add/sub/compare (radix-complement; the ONE home of the
# lazy-add + deferred-carry-resolve idiom that pi.py and modular.py used
# to hand-roll separately).
# ---------------------------------------------------------------------------

def _mask(digit_bits: int) -> jnp.ndarray:
    return jnp.uint32((1 << digit_bits) - 1)


def _pad_to(x: jax.Array, n: int) -> jax.Array:
    m = x.shape[-1]
    if m == n:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n - m)])


def add_digits(a: jax.Array, b: jax.Array,
               digit_bits: int = DIGIT_BITS) -> jax.Array:
    """a + b on equal-width normalized digit arrays, same width (the
    carry out of the top digit, if any, is dropped -- size the arrays)."""
    return normalize_digits(a + b, digit_bits)


def sub_digits(a: jax.Array, b: jax.Array,
               digit_bits: int = DIGIT_BITS) -> Tuple[jax.Array, jax.Array]:
    """(a - b mod B**n, ge) on equal-width normalized digit arrays.

    ge is (...,) uint32, 1 iff a >= b (the radix-complement carry out);
    the difference is the true a - b exactly when ge == 1.
    """
    n = a.shape[-1]
    mask = _mask(digit_bits)
    comp = (mask - b) & mask
    s = _pad_to(a + comp, n + 1).at[..., 0].add(1)     # lazy, < 2**(d+1)+1
    s = normalize_digits(s, digit_bits)
    return s[..., :n], s[..., n]


def ge_digits(a: jax.Array, b: jax.Array,
              digit_bits: int = DIGIT_BITS) -> jax.Array:
    """a >= b on equal-width normalized digit arrays; (...,) uint32 0/1."""
    return sub_digits(a, b, digit_bits)[1]


# ---------------------------------------------------------------------------
# Per-element dynamic shifts (normalization).  s varies across the batch,
# so digit moves are a take_along_axis roll and bit moves are uint32
# shifts by per-element amounts -- both plain VPU ops, no host round-trip.
# ---------------------------------------------------------------------------

def bit_length_digits(x: jax.Array, digit_bits: int = DIGIT_BITS) -> jax.Array:
    """Bit length of each batched digit-array value; (...,) uint32.

    bitlen(digit) = sum_k [digit >= 2**k] (branch-free, d static steps);
    the value's bit length is the max over nonzero digits of
    (digit_index * d + bitlen).  Returns 0 for zero values.
    """
    x = jnp.asarray(x, U32)
    bl = jnp.zeros(x.shape, U32)
    for k in range(digit_bits):
        bl = bl + (x >= jnp.uint32(1 << k)).astype(U32)
    pos = jnp.asarray(np.arange(x.shape[-1], dtype=np.uint32) * digit_bits)
    return jnp.max(jnp.where(x > 0, bl + pos, jnp.uint32(0)), axis=-1)


def shift_left_bits(x: jax.Array, s: jax.Array,
                    digit_bits: int = DIGIT_BITS) -> jax.Array:
    """x << s per batch element, within the (fixed) digit width.

    s: (...,) uint32 with 0 <= s < width*d; callers guarantee the shifted
    value still fits (bits shifted past the top are lost).
    """
    x = jnp.asarray(x, U32)
    n = x.shape[-1]
    d = jnp.uint32(digit_bits)
    sd = (s // d).astype(jnp.int32)[..., None]
    sb = (s % d).astype(U32)[..., None]
    pos = jnp.arange(n, dtype=jnp.int32)
    src = pos - sd                                     # digit roll up by sd
    g = jnp.take_along_axis(
        jnp.broadcast_to(x, sd.shape[:-1] + (n,)),
        jnp.clip(src, 0, n - 1), axis=-1)
    g = jnp.where(src >= 0, g, jnp.uint32(0))
    prev = jnp.concatenate(
        [jnp.zeros(g.shape[:-1] + (1,), U32), g[..., :-1]], axis=-1)
    # sb == 0: prev >> d vanishes (digits < 2**d), no special case needed
    return ((g << sb) & _mask(digit_bits)) | (prev >> (d - sb))


def shift_right_bits(x: jax.Array, s: jax.Array,
                     digit_bits: int = DIGIT_BITS) -> jax.Array:
    """x >> s per batch element (bits shifted out are dropped)."""
    x = jnp.asarray(x, U32)
    n = x.shape[-1]
    d = jnp.uint32(digit_bits)
    sd = (s // d).astype(jnp.int32)[..., None]
    sb = (s % d).astype(U32)[..., None]
    pos = jnp.arange(n, dtype=jnp.int32)
    src = pos + sd                                     # digit roll down by sd
    g = jnp.take_along_axis(
        jnp.broadcast_to(x, sd.shape[:-1] + (n,)),
        jnp.clip(src, 0, n - 1), axis=-1)
    g = jnp.where(src <= n - 1, g, jnp.uint32(0))
    nxt = jnp.concatenate(
        [g[..., 1:], jnp.zeros(g.shape[:-1] + (1,), U32)], axis=-1)
    return (g >> sb) | ((nxt << (d - sb)) & _mask(digit_bits))


# ---------------------------------------------------------------------------
# The multiply every division step rides on: route digit arrays through
# mul_limbs32(method="auto") so division inherits the whole unified
# pipeline (VnC / fused Karatsuba / MXU kernels + autotune cache).
# ---------------------------------------------------------------------------

def mul_digits_via_pipeline(a: jax.Array, b: jax.Array,
                            digit_bits: int = DIGIT_BITS,
                            b_const: int | None = None) -> jax.Array:
    """(..., m) x (..., m) normalized digits -> (..., 2m) full product,
    computed by packing to 32-bit limbs and dispatching through
    core/mul.select_method (the autotuned multiply pipeline).

    ``b_const`` declares b a host-known fixed value (every lane equal to
    it): the NTT tier then reuses its cached forward transform
    (kernels/ntt_mul prepared operands); other tiers ignore it."""
    m = a.shape[-1]
    # the Pallas entry points flatten leading axes per operand, so an
    # unbatched constant (e.g. a reciprocal row) must be broadcast to
    # the batch shape BEFORE dispatch, not left to jnp broadcasting
    lead = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    a = jnp.broadcast_to(a, lead + (m,))
    b = jnp.broadcast_to(b, lead + (m,))
    m32 = -(-(m * digit_bits) // 32)
    a32 = join_digits(a, digit_bits, m32)
    b32 = join_digits(b, digit_bits, m32)
    p32 = mul_limbs32(a32, b32, method="auto",
                      b_const=b_const)                 # (..., 2*m32)
    return split_digits(p32, digit_bits)[..., : 2 * m]


def _mul_equalized(a: jax.Array, b: jax.Array,
                   digit_bits: int = DIGIT_BITS,
                   b_const: int | None = None) -> jax.Array:
    """Pad to a common width and multiply via the pipeline; (..., wa+wb).
    Zero-padding does not change b's value, so ``b_const`` passes through."""
    wa, wb = a.shape[-1], b.shape[-1]
    w = max(wa, wb)
    p = mul_digits_via_pipeline(_pad_to(a, w), _pad_to(b, w), digit_bits,
                                b_const=b_const)
    return p[..., : wa + wb]


# ---------------------------------------------------------------------------
# Small-divisor fast path (the pi workload): divisor is a host Python int
# < 2**digit_bits, one uint32 divide per digit, MSB-first scan.
# ---------------------------------------------------------------------------

def div_small(x: jax.Array, s, digit_bits: int = DIGIT_BITS) -> jax.Array:
    """Exact floor-division of (..., m) normalized digits by a small
    positive int s < 2**digit_bits: scan from the most significant digit
    with a running remainder (r*B + d < 2**32 stays exact in uint32)."""
    s = jnp.uint32(s)
    bits = jnp.uint32(digit_bits)

    def step(r, d):
        cur = (r << bits) | d
        q = cur // s
        return cur - q * s, q

    x_t = jnp.moveaxis(jnp.asarray(x, U32), -1, 0)[::-1]      # MSB first
    _, q_t = jax.lax.scan(step, jnp.zeros(x.shape[:-1], U32), x_t)
    return jnp.moveaxis(q_t[::-1], 0, -1)


# ---------------------------------------------------------------------------
# Newton-Raphson reciprocal (precision doubling).
# ---------------------------------------------------------------------------

def recip_digits(b_norm: jax.Array,
                 digit_bits: int = DIGIT_BITS,
                 b_norm_int: int | None = None) -> jax.Array:
    """v ~= floor(D**(2*nb) / b_norm) for top-bit-normalized divisors.

    b_norm: (..., nb) normalized digits with the top bit set, i.e. value
    in [D**nb / 2, D**nb).  Returns (..., nb + 1) digits.

    Precision doubling: level p holds v_p ~= D**(2p) / Bp where Bp is the
    top p digits of b_norm (a STATIC slice, thanks to normalization --
    this is what makes the divide-and-conquer shapes trace-time static).
    One exact-integer Newton step per level:

        x   = v_p * D**(q-p)                  (shift; q = min(2p, nb))
        v_q = floor(x * (2*D**(2q) - x*Bq) / D**(2q))

    Both multiplies are exact and route through the multiply pipeline;
    only the final floor truncates, so by the parabola bound
    x*(2*T - x*Bq)/T <= T/Bq the invariant v_p <= D**(2p)/Bp holds at
    every level: the reciprocal NEVER overestimates, which is what lets
    divmod correct with forward (add-only) steps.  Total multiply work is
    a geometric series ~= 3 full-width products.

    ``b_norm_int`` declares the divisor a host-known constant (equal in
    every lane to b_norm's value): each level's top-q-digit slice Bq is
    then itself host-known (b_norm_int >> ((nb-q) * digit_bits)), so
    every x*Bq multiply rides the prepared-operand NTT cache.
    """
    nb = b_norm.shape[-1]
    D = 1 << digit_bits
    b_norm = jnp.asarray(b_norm, U32)
    lead = b_norm.shape[:-1]

    # base: p = 1.  v1 = floor((D**2 - 1) / B1) in [D+1, 2D-1]; the -1
    # (vs true D**2) keeps the numerator in uint32 and only ever rounds
    # down (error <= 1 ulp, washed out by the first doubling).
    v = jnp.uint32(D * D - 1) // b_norm[..., nb - 1:nb]
    v = jnp.concatenate([v & _mask(digit_bits),
                         v >> jnp.uint32(digit_bits)], axis=-1)  # (..., 2)
    def newton_step(v, p, q):
        Bq = b_norm[..., nb - q:]                      # (..., q)
        Bq_int = (b_norm_int >> ((nb - q) * digit_bits)
                  if b_norm_int is not None else None)
        x = jnp.concatenate(
            [jnp.zeros(lead + (q - p,), U32), v], axis=-1)  # (..., q+1)
        t1 = _mul_equalized(x, Bq, digit_bits,
                            b_const=Bq_int)            # (..., 2q+1), < 2*D**2q
        two = jnp.zeros(lead + (2 * q + 1,), U32).at[..., 2 * q].set(2)
        u, _ = sub_digits(two, _pad_to(t1, 2 * q + 1), digit_bits)
        prod = _mul_equalized(x, u, digit_bits)        # (..., 3q+2)
        return prod[..., 2 * q: 3 * q + 1]             # floor(x*u / D**2q)

    p = 1
    while p < nb:
        q = min(2 * p, nb)
        v = newton_step(v, p, q)
        p = q
    # one full-precision polish step: each doubling level's floor adds
    # ~1 ulp of undershoot, which COMPOUNDS quadratically up the ladder
    # (tens of ulps by 512 bits).  A final same-precision iteration
    # squares the accumulated error back below a few ulps, keeping the
    # divmod correction loop's trip count O(1).
    if nb > 1:
        v = newton_step(v, nb, nb)
    return v                                           # (..., nb+1)


def recip_limbs32(b_limbs: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Batched Newton reciprocal on 32-bit limb arrays.

    Returns (v_limbs, shift): with N = 32*mb total bits and the
    per-element shift s normalizing b (b << s has its top bit at N-1),
    v ~= floor(2**(2N) / (b << s)) in (mb + 1) limbs.  The approximation
    never overestimates and undershoots by at most a few units --
    exactness is restored by divmod's correction loop, which is why the
    pair (v, shift) is all a caller needs to divide by b with one
    multiply per quotient.
    """
    b = jnp.asarray(b_limbs, U32)
    mb = b.shape[-1]
    b_d = split_digits(b, DIGIT_BITS)
    nbd = b_d.shape[-1]
    s = jnp.uint32(nbd * DIGIT_BITS) - bit_length_digits(b_d, DIGIT_BITS)
    b_n = shift_left_bits(b_d, s, DIGIT_BITS)
    v = recip_digits(b_n, DIGIT_BITS)                  # (..., nbd+1)
    m_out = mb + 1
    return join_digits(_pad_to(v, 2 * m_out), DIGIT_BITS, m_out), s


# ---------------------------------------------------------------------------
# divmod: quotient = one multiply by the reciprocal, remainder = one
# multiply back + branch-free masked correction.
# ---------------------------------------------------------------------------

def _masked_sub(x: jax.Array, y: jax.Array, mask: jax.Array,
                digit_bits: int) -> jax.Array:
    """x - y on lanes where mask == 1 (callers guarantee x >= y there)."""
    return sub_digits(x, y * mask[..., None], digit_bits)[0]


def _plus_one(q: jax.Array, mask: jax.Array, digit_bits: int) -> jax.Array:
    return normalize_digits(q.at[..., 0].add(mask), digit_bits)


def _minus_one(q: jax.Array, mask: jax.Array, digit_bits: int) -> jax.Array:
    one = jnp.zeros_like(q).at[..., 0].set(1)
    return _masked_sub(q, one, mask, digit_bits)


def _correct_qr(a_c, b_c, q, p, digit_bits):
    """Exact (q, r) from an approximate quotient q with p = q*b.

    a_c, b_c, p: equal-width digit arrays; q any width.  Two masked
    while-loops: pull q down while q*b > a (never entered when q came
    from the non-overestimating Newton reciprocal; kept for safety),
    then push q up while a - q*b >= b.  Loop trip count == per-lane
    quotient error; zero-divisor lanes are masked out so the loops
    terminate (their q/r are undefined).
    """
    bnz = (jnp.max(b_c, axis=-1) > 0).astype(U32)

    def cond_hi(st):
        q, p = st
        over = (1 - ge_digits(a_c, p, digit_bits)) * bnz
        return jnp.any(over == 1)

    def body_hi(st):
        q, p = st
        over = (1 - ge_digits(a_c, p, digit_bits)) * bnz
        return _minus_one(q, over, digit_bits), \
            _masked_sub(p, b_c, over, digit_bits)

    q, p = jax.lax.while_loop(cond_hi, body_hi, (q, p))
    r, _ = sub_digits(a_c, p, digit_bits)

    def cond_lo(st):
        q, r = st
        under = ge_digits(r, b_c, digit_bits) * bnz
        return jnp.any(under == 1)

    def body_lo(st):
        q, r = st
        under = ge_digits(r, b_c, digit_bits) * bnz
        return _plus_one(q, under, digit_bits), \
            _masked_sub(r, b_c, under, digit_bits)

    return jax.lax.while_loop(cond_lo, body_lo, (q, r))


def divmod_recip_digits(a: jax.Array, b: jax.Array,
                        digit_bits: int = DIGIT_BITS,
                        b_const: int | None = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Reciprocal-divide: (..., na) // (..., nb) -> ((..., na) q, (..., nb) r).

    Normalize b to the array top (per-element shift s), shift a by the
    same s (scaling numerator and denominator preserves the quotient),
    take q_hat = floor(A * v / D**(2nw)) with the Newton reciprocal v,
    and correct exactly.  One reciprocal + two full multiplies.

    The reciprocal precision must cover the QUOTIENT width, not just
    the divisor: with nw fractional digits the estimate error is
    ~ delta * A / D**(2nw) <= delta * D**(na - nw), so a reciprocal at
    divisor width alone leaves a D**(na-nb)-sized error for wide
    dividends over narrow divisors -- astronomically many +1 correction
    trips.  nw = max(na, nb) bounds the error by the reciprocal's own
    few-ulp undershoot for every shape; when na <= nb this pads
    nothing.  (The padding is a LOW-side digit shift of the normalized
    divisor, so the top bit stays at the array top and recip_digits'
    contract is unchanged.)

    ``b_const`` declares the divisor a host-known constant (every lane
    equal to it): the normalization shift and each Newton level's
    divisor slice are then host-computable, so the reciprocal chain's
    x*Bq multiplies and the q*b check multiply all hit the prepared-
    operand NTT cache (the repeat-divide-by-a-fixed-modulus pattern of
    RSA-CRT and base conversion).
    """
    a = jnp.asarray(a, U32)
    b = jnp.asarray(b, U32)
    na, nb = a.shape[-1], b.shape[-1]
    lead = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    a = jnp.broadcast_to(a, lead + (na,))
    b = jnp.broadcast_to(b, lead + (nb,))
    nw = max(na, nb)

    s = jnp.uint32(nb * digit_bits) - bit_length_digits(b, digit_bits)
    b_norm = shift_left_bits(b, s, digit_bits)
    # top-aligned widening: value b_norm * D**(nw-nb), top bit preserved
    b_pad = jnp.concatenate(
        [jnp.zeros(lead + (nw - nb,), U32), b_norm], axis=-1)
    a_s = shift_left_bits(_pad_to(a, na + nb), s, digit_bits)
    A = jnp.concatenate(
        [jnp.zeros(lead + (nw - nb,), U32), a_s], axis=-1)  # (..., na+nw)
    b_pad_int = None
    if b_const is not None:
        b_int = int(b_const)
        assert b_int >= 1
        # the device-computed s equals this host value on every lane
        s_int = nb * digit_bits - b_int.bit_length()
        b_pad_int = (b_int << s_int) << ((nw - nb) * digit_bits)
    v = recip_digits(b_pad, digit_bits,
                     b_norm_int=b_pad_int)             # (..., nw+1)

    prod = _mul_equalized(A, v, digit_bits)            # (..., na+2nw+1)
    q = prod[..., 2 * nw: 2 * nw + na]                 # q_hat <= q < D**na

    wc = nw + 1                  # covers a (< D**na) AND b (< D**nb)
    p = _mul_equalized(q, b, digit_bits,
                       b_const=b_const)[..., :wc]      # q_hat*b <= a < D**na
    q, r = _correct_qr(_pad_to(a, wc), _pad_to(b, wc), q, p, digit_bits)
    return q, r[..., :nb]


def select_div_method(nbits_a: int, nbits_b: int, batch: int = 1) -> str:
    """Size-based division dispatch (configs/dot_bignum.DIV_DISPATCH).

    Knuth-D in the fused Pallas kernel ("schoolbook") up to the config
    threshold: its O(na*nb) digit steps stay VMEM-resident and beat the
    Newton chain's multiply launches at small widths.  Above it,
    reciprocal-divide ("recip"): the Newton multiplies route through the
    autotuned pipeline, so asymptotics follow the multiply backends.

    Batch awareness mirrors mul.select_method: a kernel launch only
    amortizes over the batch axis, so tiny batches take the reciprocal
    path, whose multiplies then themselves dispatch to the small-batch
    jnp compositions.

    A ``repro.api.configure(div_method=...)`` override wins over
    everything; the REPRO_DIV_BACKEND env var is its deprecated alias.
    """
    from repro import config as _rc
    from repro.configs.dot_bignum import DIV_DISPATCH, MUL_DISPATCH
    from repro.obs import trace as _trace

    nbits = max(nbits_a, nbits_b)
    override = _rc.resolve("div_method", DIV_METHODS, "division method")
    if override:
        choice, rule, detail = override, "override", {}
    elif batch < MUL_DISPATCH.kernel_min_batch:
        choice, rule = "recip", "kernel_min_batch"
        detail = {"threshold": MUL_DISPATCH.kernel_min_batch}
    elif nbits <= DIV_DISPATCH.schoolbook_max_bits:
        choice, rule = "schoolbook", "schoolbook_max_bits"
        detail = {"threshold": DIV_DISPATCH.schoolbook_max_bits}
    else:
        choice, rule = "recip", "above_schoolbook_max_bits"
        detail = {"threshold": DIV_DISPATCH.schoolbook_max_bits}
    _trace.emit("div", nbits, batch, choice, rule,
                nbits_a=nbits_a, nbits_b=nbits_b, **detail)
    return choice


def divmod_digits(a: jax.Array, b: jax.Array,
                  digit_bits: int = DIGIT_BITS, method: str = "auto",
                  b_const: int | None = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Exact (floor quotient, remainder) on normalized digit arrays.

    a: (..., na), b: (..., nb) with broadcastable leading shapes; returns
    ((..., na), (..., nb)).  Invariant: q*b + r == a and 0 <= r < b for
    every lane with b >= 1 (b == 0 lanes are undefined).  The Pallas
    schoolbook kernel only supports the native 16-bit digits; other
    digit_bits always take the reciprocal path.  ``b_const`` declares
    the divisor a host-known constant so the reciprocal path's fixed-
    operand multiplies hit the prepared-operand NTT cache (the
    schoolbook kernel ignores it).
    """
    if method == "auto":
        batch = 1
        for d in jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]):
            batch *= int(d)
        method = select_div_method(a.shape[-1] * digit_bits,
                                   b.shape[-1] * digit_bits, batch)
    if method == "schoolbook" and digit_bits != 16:
        method = "recip"
    if method == "schoolbook":
        from repro.kernels.dot_div import ops as _dops
        from repro.resilience import guard as _guard
        a2 = jnp.asarray(a, U32)
        b2 = jnp.asarray(b, U32)
        lead = jnp.broadcast_shapes(a2.shape[:-1], b2.shape[:-1])
        na, nb = a2.shape[-1], b2.shape[-1]
        a2 = jnp.broadcast_to(a2, lead + (na,)).reshape((-1, na))
        b2 = jnp.broadcast_to(b2, lead + (nb,)).reshape((-1, nb))
        q, r = _guard.run("div", na * digit_bits, [
            ("pallas", lambda: _dops.dot_divmod_digits(a2, b2)),
            ("jnp", lambda: divmod_recip_digits(a2, b2, digit_bits,
                                                b_const=b_const)),
        ])
        return q.reshape(lead + (na,)), r.reshape(lead + (nb,))
    if method != "recip":
        raise ValueError(
            f"unknown division method {method!r}; choose from "
            f"{('auto',) + DIV_METHODS} (REPRO_DIV_BACKEND accepts the "
            f"same names, minus 'auto')")
    return divmod_recip_digits(a, b, digit_bits, b_const=b_const)


def divmod_limbs32(a_limbs: jax.Array, b_limbs: jax.Array,
                   method: str = "auto",
                   b_const: int | None = None) -> Tuple[jax.Array, jax.Array]:
    """(..., ma) // (..., mb) uint32 limbs -> ((..., ma) q, (..., mb) r).

    The GMP/OpenSSL-facing entry point (saturated radix in/out, digit
    radix inside -- same packing contract as mul_limbs32, including the
    ``b_const`` fixed-divisor declaration).
    """
    ma = a_limbs.shape[-1]
    mb = b_limbs.shape[-1]
    a_d = split_digits(jnp.asarray(a_limbs, U32), DIGIT_BITS)
    b_d = split_digits(jnp.asarray(b_limbs, U32), DIGIT_BITS)
    q_d, r_d = divmod_digits(a_d, b_d, DIGIT_BITS, method, b_const=b_const)
    return (join_digits(q_d, DIGIT_BITS, ma),
            join_digits(r_d, DIGIT_BITS, mb))


@functools.partial(jax.jit, static_argnames=("method", "b_const"))
def divmod_jit(a_limbs: jax.Array, b_limbs: jax.Array, method: str = "auto",
               b_const: int | None = None):
    return divmod_limbs32(a_limbs, b_limbs, method, b_const=b_const)


# ---------------------------------------------------------------------------
# Constant (host-known) divisors: the reciprocal is EXACT Python-int
# math, so the quotient needs one multiply and at most ONE fix-up step
# (branch-free select, no loop).  This is the base-conversion workhorse.
# ---------------------------------------------------------------------------

def divmod_const(x: jax.Array, c: int,
                 digit_bits: int = DIGIT_BITS) -> Tuple[jax.Array, jax.Array]:
    """(x // c, x % c) for a host-side Python int divisor c >= 1.

    v = floor(D**m / c) is exact, so q_hat = floor(x*v / D**m) is q or
    q-1 (never more): one conditional add/sub pair finishes the job.
    Returns (q: (..., m), r: (..., nc)) with nc = digit width of c.
    """
    assert c >= 1
    x = jnp.asarray(x, U32)
    m = x.shape[-1]
    D = 1 << digit_bits
    nc = max(1, -(-c.bit_length() // digit_bits))
    assert c < D ** m, "divisor wider than the dividend array"
    v_int = D ** m // c
    v = jnp.asarray(L.int_to_limbs(v_int, m + 1, digit_bits))
    c_arr = jnp.asarray(L.int_to_limbs(c, nc, digit_bits))

    # both operands of both multiplies are host-known: they ride the
    # prepared-operand NTT cache whenever the width dispatches to "ntt"
    q = _mul_equalized(x, v, digit_bits, b_const=v_int)[..., m: 2 * m]
    p = _mul_equalized(q, c_arr, digit_bits, b_const=c)[..., : m + 1]
    r, _ = sub_digits(_pad_to(x, m + 1), p, digit_bits)
    c_w = jnp.broadcast_to(_pad_to(c_arr, m + 1), r.shape)
    under = ge_digits(r, c_w, digit_bits)              # q_hat == q - 1
    q = _plus_one(q, under, digit_bits)
    r = _masked_sub(r, c_w, under, digit_bits)
    return q, r[..., :nc]


# ---------------------------------------------------------------------------
# On-device base conversion: limbs -> decimal digits by divide-and-
# conquer divmod on 10**k chunks (subquadratic: both halves shrink, and
# every divmod is one pipeline multiply thanks to exact reciprocals).
# ---------------------------------------------------------------------------

DEC_CHUNK = 4                       # decimal digits per leaf (10**4 < 2**14)


def _dec_width(n_dec: int, digit_bits: int) -> int:
    """Digits needed to hold any value < 10**n_dec."""
    return max(1, -(-((10 ** n_dec - 1).bit_length()) // digit_bits))


def to_decimal_digits(x: jax.Array, n_dec: int,
                      digit_bits: int = DIGIT_BITS) -> jax.Array:
    """(..., m) digit-array values < 10**n_dec -> (..., n_dec) decimal
    digits, MOST significant first, entirely on device.

    Divide-and-conquer: split by divmod_const(x, 10**(4*half)) until each
    chunk holds 4 decimal digits, then extract them with elementwise
    uint32 ops.  T(n) = 2 T(n/2) + mul(n): subquadratic with any
    subquadratic multiply backend (the divisors are host-known powers of
    ten, so every split is ONE pipeline multiply -- see divmod_const).
    """
    x = jnp.asarray(x, U32)
    nch = -(-n_dec // DEC_CHUNK)

    def leaf(v: jax.Array) -> jax.Array:
        # v: (..., w) digits, value < 10**4 < 2**14: collapse to scalar
        val = jnp.zeros(v.shape[:-1], U32)
        for i in range(v.shape[-1]):
            val = val | (v[..., i] << jnp.uint32(digit_bits * i))
        outs = [(val // jnp.uint32(10 ** (DEC_CHUNK - 1 - j)))
                % jnp.uint32(10) for j in range(DEC_CHUNK)]
        return jnp.stack(outs, axis=-1)                # (..., 4) MSB first

    def rec(v: jax.Array, chunks: int) -> jax.Array:
        if chunks == 1:
            return leaf(v)
        lo_n = chunks // 2
        hi_n = chunks - lo_n
        q, r = divmod_const(v, 10 ** (DEC_CHUNK * lo_n), digit_bits)
        q = q[..., : _dec_width(DEC_CHUNK * hi_n, digit_bits)]
        r = _pad_to(r, _dec_width(DEC_CHUNK * lo_n, digit_bits))
        return jnp.concatenate([rec(q, hi_n), rec(r, lo_n)], axis=-1)

    dec = rec(x[..., : _dec_width(DEC_CHUNK * nch, digit_bits)]
              if x.shape[-1] >= _dec_width(DEC_CHUNK * nch, digit_bits)
              else _pad_to(x, _dec_width(DEC_CHUNK * nch, digit_bits)), nch)
    return dec[..., DEC_CHUNK * nch - n_dec:]


def to_decimal_limbs32(x_limbs: jax.Array, n_dec: int) -> jax.Array:
    """32-bit limb entry point of to_decimal_digits."""
    return to_decimal_digits(
        split_digits(jnp.asarray(x_limbs, U32), DIGIT_BITS), n_dec,
        DIGIT_BITS)
