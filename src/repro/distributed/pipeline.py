"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The production mesh fixes (pod, data, model); pipeline stages are an
OPTIONAL alternative mapping of one axis (config `pp_axis`).  Stages hold
contiguous layer groups; microbatches flow through a bubble schedule:

  step t: stage s computes microbatch (t - s) if 0 <= t - s < M,
          then ppermutes its activation to stage s+1.

Communication is one ppermute per step (point-to-point over ICI), which
XLA lowers to async collective-permute -- the compute of step t+1
overlaps the send of step t.  Correctness is tested against the
unpipelined stack on a subprocess mesh (tests/test_distributed.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(stage_fn: Callable, n_stages: int, microbatches: int,
                     axis_name: str = "stage"):
    """Build the per-device pipelined forward for shard_map.

    stage_fn(stage_params, x) -> x          (one stage's layer group)
    Returns fn(stage_params_local, x_mb) where x_mb: (M, mb, ...) lives
    fully on stage 0 (other stages receive zeros) and the result is the
    final stage's outputs, broadcast back via ppermute ring closure.
    """

    def fn(stage_params, x_mb):
        # each device's slice of the stacked params keeps a leading dim of 1
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        sid = jax.lax.axis_index(axis_name)
        M = microbatches
        S = n_stages
        mb_shape = x_mb.shape[1:]
        buf = jnp.zeros(mb_shape, x_mb.dtype)          # current activation
        out = jnp.zeros_like(x_mb)                     # collected outputs
        fwd = [(i, (i + 1) % S) for i in range(S)]

        for t in range(M + S - 1):
            # stage 0 ingests microbatch t (if any)
            if t < M:
                buf = jnp.where(sid == 0, x_mb[t], buf)
            y = stage_fn(stage_params, buf)
            # last stage records its finished microbatch (t - (S-1))
            rec = t - (S - 1)
            if 0 <= rec < M:
                out = jnp.where(sid == S - 1,
                                out.at[rec].set(y), out)
            # shift activations to the next stage
            buf = jax.lax.ppermute(y, axis_name, fwd)
        # broadcast final outputs from the last stage to everyone
        out = jax.lax.psum(
            jnp.where(sid == S - 1, out, jnp.zeros_like(out)), axis_name)
        return out

    return fn


def run_pipelined(mesh: Mesh, stage_fn, stage_params_stacked, x,
                  microbatches: int, axis_name: str = "stage"):
    """stage_params_stacked: (S, ...) pytree; x: (batch, ...) on host.
    Splits batch into microbatches, shard_maps over the stage axis."""
    S = mesh.shape[axis_name]
    B = x.shape[0]
    assert B % microbatches == 0
    x_mb = x.reshape(microbatches, B // microbatches, *x.shape[1:])

    fn = pipeline_forward(stage_fn, S, microbatches, axis_name)
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis_name), P()),      # params sharded by stage
        out_specs=P(),
    )
    out_mb = mapped(stage_params_stacked, x_mb)
    return out_mb.reshape(B, *x.shape[1:])
