"""Logical-axis sharding rules with divisibility pruning.

Rules map param-tree path suffixes to per-dim axis templates.  A template
axis is kept only when the dim size divides the mesh axis size — this is
what makes the same rule table serve granite (kv=8 < TP: replicate KV),
minicpm3 (40 heads: latent-dim TP instead), olmoe (64 experts: EP=16), and
every other assigned arch without per-arch special cases.  Stacked layer
params (leading L dim) are handled by right-aligning templates.

DP axes: batch dims shard over ("pod", "data") jointly; when a batch dim
is too small (long_500k: B=1), the sequence dim of caches takes the DP
axes instead (context-sharded KV: the production long-context layout).
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path-suffix, per-dim template right-aligned to the trailing dims)
PARAM_RULES = (
    ("embed", ("model", None)),
    ("lm_head", (None, "model")),
    # attention (GQA / shared zamba block / encoder / decoder)
    ("attn/wq", (None, "model")),
    ("attn/wk", (None, "model")),
    ("attn/wv", (None, "model")),
    ("attn/wo", ("model", None)),
    ("xattn/wq", (None, "model")),
    ("xattn/wk", (None, "model")),
    ("xattn/wv", (None, "model")),
    ("xattn/wo", ("model", None)),
    # MLA: latent-dim TP (head counts may not divide the model axis)
    ("attn/wdq", (None, "model")),
    ("attn/wuq", ("model", None)),
    ("attn/wdkv", (None, None)),
    ("attn/wukv", (None, None)),
    # dense MLP
    ("mlp/wg", (None, "model")),
    ("mlp/wu", (None, "model")),
    ("mlp/wd", ("model", None)),
    # MoE: Megatron-ordered feature TP (SSPerf iterations 2-4):
    # expert-dim sharding (EP) makes the data-dependent dispatch
    # unpartitionable, and contraction-dim-first sharding all-reduces the
    # (5x capacity-inflated) buffers in f32 during backward.  Col-parallel
    # wg/wu (d_ff output sharded, no fwd comm) then row-parallel wd (one
    # reduction, placeable after the linear combine) is the cheap order.
    ("moe/router", (None, None)),
    ("moe/wg", (None, None, "model")),
    ("moe/wu", (None, None, "model")),
    ("moe/wd", (None, "model", None)),
    # mamba2
    ("mamba/wz", (None, "model")),
    ("mamba/wx", (None, "model")),
    ("mamba/wB", (None, None)),
    ("mamba/wC", (None, None)),
    ("mamba/wdt", (None, None)),
    ("mamba/conv_w", (None, None)),
    ("mamba/conv_b", (None,)),
    ("mamba/norm", ("model",)),
    ("mamba/out_proj", ("model", None)),
    # rwkv6
    ("tm/wr", (None, "model")),
    ("tm/wk", (None, "model")),
    ("tm/wv", (None, "model")),
    ("tm/wg", (None, "model")),
    ("tm/wo", ("model", None)),
    ("tm/w_a", (None, None)),
    ("tm/w_b", (None, "model")),
    ("tm/w0", ("model",)),
    ("tm/u", ("model",)),
    ("tm/ln_x", ("model",)),
    ("cm/wk", (None, "model")),
    ("cm/wv", ("model", None)),
    ("cm/wr", (None, "model")),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _resolve(template, shape, mesh: Mesh) -> P:
    """Right-align template to shape; prune non-divisible axes."""
    ndim = len(shape)
    full = (None,) * (ndim - len(template)) + tuple(template)
    out = []
    for d, ax in enumerate(full):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if shape[d] % size == 0 else None)
    return P(*out)


def spec_for_param(path, shape, mesh: Mesh, fsdp: bool = True,
                   fsdp_min_size: int = 1 << 20) -> P:
    ps = _path_str(path)
    spec = P(*(None,) * len(shape))
    for suffix, template in PARAM_RULES:
        if ps.endswith(suffix):
            if _ROW_ATTN["on"] and suffix in _ROW_ATTN_RULES:
                template = _ROW_ATTN_RULES[suffix]
            spec = _resolve(template, shape, mesh)
            break
    if not fsdp or int(np.prod(shape)) < fsdp_min_size:
        return spec
    # FSDP/ZeRO-3: shard one more dim over the DP axes so params+optimizer
    # state scale with the full chip count (the SPMD partitioner inserts the
    # per-layer all-gather / reduce-scatter pair).  Never shard the leading
    # scan-stack dim (segment slicing would force resharding).
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)
    dims = list(spec)
    first_ok = 1 if len(shape) >= 3 else 0
    cands = sorted((d for d in range(first_ok, len(shape))
                    if dims[d] is None and shape[d] % dpn == 0),
                   key=lambda d: -shape[d])
    if cands:
        dims[cands[0]] = dp
    return P(*dims)


def param_pspecs(params_tree, mesh: Mesh, fsdp: bool = True):
    """ShapeDtypeStruct (or array) tree -> PartitionSpec tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(path, leaf.shape, mesh, fsdp),
        params_tree)


def param_shardings(params_tree, mesh: Mesh, fsdp: bool = True):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_tree, mesh, fsdp))


# ---------------------------------------------------------------------------
# Batch / cache shardings (shape-aware)
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def batch_pspecs(batch_tree, mesh: Mesh):
    """Shard dim 0 (global batch) over the DP axes when divisible."""
    dp = dp_axes(mesh)

    def spec(leaf):
        if leaf.shape and leaf.shape[0] % dp_size(mesh) == 0:
            return P(dp, *(None,) * (len(leaf.shape) - 1))
        return P(*(None,) * len(leaf.shape))

    return jax.tree.map(spec, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh, batch: int, seq: int):
    """KV caches / SSM states: batch over DP if divisible, else the cache
    sequence dim takes DP (context sharding); kv-heads/state-heads over
    the model axis when divisible."""
    dp = dp_axes(mesh)
    batch_ok = batch % dp_size(mesh) == 0

    def spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        shape = leaf.shape
        dims = [None] * len(shape)
        for d, sz in enumerate(shape):
            if sz == batch and dims.count(dp) == 0 and batch_ok and d < 2:
                dims[d] = dp
                break
        if not batch_ok:
            for d, sz in enumerate(shape):
                if sz == seq and sz % dp_size(mesh) == 0:
                    dims[d] = dp
                    break
        # heads / model-parallel dims
        if name in ("k", "v"):
            hd_dim = len(shape) - 2          # (..., B, S, K, hd)
            seq_dim = len(shape) - 3
            if shape[hd_dim] % mesh.shape["model"] == 0:
                dims[hd_dim] = "model"
            elif (dims[seq_dim] is None
                  and shape[seq_dim] % mesh.shape["model"] == 0):
                # kv-heads don't divide TP (granite kv=8 @ TP16): shard the
                # cache SEQUENCE over the model axis instead -- decode
                # attention reduces over seq, so XLA inserts only tiny
                # softmax all-reduces while cache reads and residency drop
                # by the TP degree (SSPerf cell 3, iteration 2).
                dims[seq_dim] = "model"
        elif name in ("h",):                  # mamba (..., B, nh, hp, ds)
            d = len(shape) - 3
            if shape[d] % mesh.shape["model"] == 0:
                dims[d] = "model"
        elif name in ("S",):                  # rwkv (..., B, nh, hd, hd)
            d = len(shape) - 3
            if shape[d] % mesh.shape["model"] == 0:
                dims[d] = "model"
        elif name in ("conv",):                # (..., B, ck-1, C)
            d = len(shape) - 1
            if shape[d] % mesh.shape["model"] == 0:
                dims[d] = "model"
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def to_shardings(pspec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        pspec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Explicit FSDP gather.
#
# Storage sharding puts the DP axes on one dim of every large param
# (ZeRO-3).  Left to itself, the SPMD partitioner may resolve the
# "contraction dim sharded on the same axis as the batch" conflict by
# replicating ACTIVATIONS over DP (measured: 12x flops on smollm train).
# The fix is the standard explicit-FSDP pattern: inside each scanned layer
# body, constrain the (per-layer, already sliced) params back to their
# rule sharding WITHOUT the DP axes -- a just-in-time per-layer weight
# all-gather, whose reverse (for grads) is a reduce-scatter.
# ---------------------------------------------------------------------------

_FSDP_CTX = {"mesh": None}
_ROW_ATTN = {"on": False}


def set_attn_row_parallel(on: bool):
    """Decode-mode attention sharding: project q/k/v row-parallel (d_model
    contraction sharded, heads REPLICATED) so the model axis is free to
    shard the KV-cache sequence dim.  Heads-TP + seq-sharded cache would
    fight over the same axis and force whole-cache all-gathers
    (SSPerf cell 3, iteration 4)."""
    _ROW_ATTN["on"] = on


_ROW_ATTN_RULES = {
    "attn/wq": ("model", None),
    "attn/wk": ("model", None),
    "attn/wv": ("model", None),
    "attn/wo": (None, None),
}


def enable_fsdp(mesh: Mesh):
    _FSDP_CTX["mesh"] = mesh


def disable_fsdp():
    _FSDP_CTX["mesh"] = None


def constrain(x, *template):
    """Activation sharding constraint with divisibility pruning.

    template entries: None, "model", or "dp" (expands to the mesh's DP
    axes).  No-op when no mesh context is active (single-device tests).
    """
    mesh = _FSDP_CTX["mesh"]
    if mesh is None:
        return x
    expanded = tuple(dp_axes(mesh) if t == "dp" else t for t in template)
    spec = _resolve(expanded, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_params(tree):
    """Inside-jit: all-gather FSDP-sharded params to their compute layout.

    Identity when FSDP is disabled (single-device tests).  Matches params
    by tree-path suffix, so it works on layer-sliced subtrees too.
    """
    mesh = _FSDP_CTX["mesh"]
    if mesh is None:
        return tree

    def constrain(path, leaf):
        spec = spec_for_param(path, leaf.shape, mesh, fsdp=False)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(constrain, tree)
