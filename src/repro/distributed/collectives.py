"""Distributed-optimization collectives.

  * exact_psum_tree      -- order/topology-invariant integer gradient
                            reduction (the paper's deferred-carry insight
                            at cluster scale; bitwise reproducible across
                            any replica count).
  * int8_ef_psum         -- int8-quantized gradient exchange with error
                            feedback: 4x less ICI traffic for the
                            collective-bound regime; the quantization
                            error is fed back next step so the long-run
                            update is unbiased.
  * allgather_matmul     -- ring all-gather overlapped with matmul
                            (collective matmul): each ppermute step
                            overlaps with the partial product of the
                            shard already on hand; hides ICI latency
                            behind MXU work on TPU.

All are shard_map-level primitives with subprocess-mesh tests
(tests/test_distributed.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import exact_accum as EA

F32 = jnp.float32


# ---------------------------------------------------------------------------
# exact integer psum
# ---------------------------------------------------------------------------

def exact_psum_tree(grad_tree, axis_name: str,
                    cfg: EA.ExactAccumConfig = EA.DEFAULT):
    """psum a gradient pytree EXACTLY: encode -> integer psum -> resolve.

    Safe for meshes up to 2**(31 - radix_bits) replicas per call
    (2048 at the default radix 20)."""

    def one(g):
        d = EA.encode(g, cfg)
        d = jax.lax.psum(d, axis_name)
        return EA.decode(EA.normalize(d, cfg), cfg)

    return jax.tree.map(one, grad_tree)


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

def int8_ef_psum(x: jax.Array, ef: jax.Array, axis_name: str,
                 n_replicas: int) -> Tuple[jax.Array, jax.Array]:
    """Mean of x across replicas, exchanged as int8; returns (mean, new_ef).

    scale is per-tensor absmax (psum'd so every replica agrees); the
    local quantization residual accumulates into `ef` and is added back
    next call (error feedback keeps the compounded update unbiased)."""
    y = x.astype(F32) + ef
    absmax = jax.lax.pmax(jnp.max(jnp.abs(y)), axis_name)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    new_ef = y - q.astype(F32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(F32) * scale / n_replicas
    return mean, new_ef


# ---------------------------------------------------------------------------
# overlapped all-gather matmul (collective matmul)
# ---------------------------------------------------------------------------

def psum_matmul_ring(x_local: jax.Array, w_local: jax.Array,
                     axis_name: str, n_shards: int,
                     chunks: int = 4) -> jax.Array:
    """x @ W with K sharded on both operands (row-parallel matmul) via a
    ring of collective-permutes instead of one monolithic all-reduce.

    x_local: (B, K/n); w_local: (K/n, N).  Each device computes its
    partial product in `chunks` column slices; slice c's ring rotation
    runs concurrently with slice c+1's matmul (on TPU, ppermute lowers to
    an async collective-permute-start/done pair, so the ICI hop hides
    behind MXU work -- the "overlap compute/comm" pattern).
    Returns (B, N) = x @ W replicated on every shard.
    """
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    n_cols = w_local.shape[1]
    csz = -(-n_cols // chunks)
    outs = []
    for c in range(chunks):
        sl = slice(c * csz, min(n_cols, (c + 1) * csz))
        partial = x_local @ w_local[:, sl]
        total = partial
        tmp = partial
        for _ in range(n_shards - 1):
            tmp = jax.lax.ppermute(tmp, axis_name, perm)
            total = total + tmp
        outs.append(total)
    return jnp.concatenate(outs, axis=1)
