"""Paper Fig. 4 (GMPbench): end-to-end workloads with DoT primitives vs
the sequential-carry baseline primitives, showing the cascade effect
(faster add/sub/mul accelerates pi, modexp, and composite workloads that
never call DoT directly).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.mul as M
from repro.core import limbs as L
from repro.core import modular as MOD
from benchmarks.util import row, time_fn

BATCH = 256


def _bench_pi(n_digits: int) -> float:
    from repro.core import pi as P
    t0 = time.perf_counter()
    P.pi_digits(n_digits)
    return time.perf_counter() - t0


def run(full: bool = False):
    rng = np.random.default_rng(3)
    out = []

    # multiply aggregate: 2048-bit karatsuba (DoT base) vs schoolbook chain
    nbits = 2048
    m = nbits // 32
    a = jnp.asarray(L.ints_to_batch(L.random_bigints(rng, BATCH, nbits), m))
    b = jnp.asarray(L.ints_to_batch(L.random_bigints(rng, BATCH, nbits), m))
    t_dot = time_fn(jax.jit(lambda x, y: M.mul_limbs32(x, y, "karatsuba")),
                    a, b, iters=5)
    t_sb = time_fn(jax.jit(lambda x, y: M.mul_limbs32(x, y, "schoolbook")),
                   a, b, iters=5)
    out.append(row("gmpbench/mul2048/dot", t_dot / BATCH,
                   f"improvement={100 * (t_sb - t_dot) / t_sb:.1f}%"))
    out.append(row("gmpbench/mul2048/baseline", t_sb / BATCH, ""))

    # modexp (the divide/powm aggregate): lazy DoT carries vs per-step
    # normalization inside Montgomery
    nbits = 512 if not full else 1024
    n = L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = MOD.mont_setup(n, nbits)
    msgs = [v % n for v in L.random_bigints(rng, 64, nbits)]
    md = jnp.asarray(np.stack([L.int_to_limbs(v, ctx.m, 16) for v in msgs]))
    ebits = jnp.asarray(MOD.exp_bits_msb(65537))
    # backend pinned to "jnp": this row compares lazy vs eager CARRY
    # handling inside the jnp Montgomery multiply; the batch-aware
    # default dispatch would route batch 64 to the fused Pallas ladder,
    # where ``lazy`` has no meaning (the kernel is lazy by construction)
    t_lazy = time_fn(jax.jit(
        lambda x: MOD.mod_exp(x, ebits, ctx, lazy=True, backend="jnp")),
        md, iters=3)
    t_eager = time_fn(jax.jit(
        lambda x: MOD.mod_exp(x, ebits, ctx, lazy=False, backend="jnp")),
        md, iters=3)
    out.append(row(f"gmpbench/modexp{nbits}/dot_lazy", t_lazy / 64,
                   f"improvement={100 * (t_eager - t_lazy) / t_eager:.1f}%"))
    out.append(row(f"gmpbench/modexp{nbits}/eager_norm", t_eager / 64, ""))

    # pi (Machin): end-to-end wall time
    nd = 200 if not full else 1000
    t_pi = _bench_pi(nd)
    out.append(row(f"gmpbench/pi_{nd}digits", t_pi, "add/sub-bound workload"))

    # gcd aggregate: batched binary GCD built entirely on DoT sub/compare
    from repro.core import gcd as G
    nbits = 512
    nd16 = nbits // 16
    xs = L.random_bigints(rng, 64, nbits)
    ys = L.random_bigints(rng, 64, nbits)
    u = jnp.asarray(np.stack([L.int_to_limbs(x, nd16, 16) for x in xs]))
    v = jnp.asarray(np.stack([L.int_to_limbs(y, nd16, 16) for y in ys]))
    t_gcd = time_fn(jax.jit(G.gcd), u, v, iters=3)
    out.append(row(f"gmpbench/gcd{nbits}", t_gcd / 64,
                   "binary GCD on DoT sub/compare primitives"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
