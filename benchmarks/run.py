"""Benchmark harness: one module per paper table/figure.

  bench_add         -> Fig. 3(a)/(b) + Table 1  (add/sub strategies)
  bench_mul         -> Table 4 + Fig. 3(d)      (multiplication routines)
  bench_breakdown   -> Tables 1 & 3             (phase-wise attribution)
  bench_gmp         -> Fig. 4                   (GMPbench-style end-to-end)
  bench_crypto      -> Fig. 5 + latency CDFs    (OpenSSL-speed-style)
  bench_exact_accum -> beyond-paper             (exact grad reduction cost)
  bench_roofline    -> EXPERIMENTS.md SSRoofline (TPU terms from the dry-run)

Prints ``name,us_per_call,derived`` CSV.  ``--full`` widens the operand
grid (slower); ``--smoke`` shrinks suites that support it to tiny sizes
and 1-2 reps (the CI bitrot guard).  Individual suites:
``python -m benchmarks.bench_add``.
"""
import argparse
import inspect
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (e.g. add,mul)")
    args = ap.parse_args()

    from benchmarks import (bench_add, bench_breakdown, bench_crypto,
                            bench_exact_accum, bench_gmp, bench_mul,
                            bench_roofline)
    suites = {
        "add": bench_add, "mul": bench_mul, "breakdown": bench_breakdown,
        "gmp": bench_gmp, "crypto": bench_crypto,
        "exact_accum": bench_exact_accum, "roofline": bench_roofline,
    }
    pick = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failures = 0
    for name in pick:
        mod = suites[name]
        t0 = time.time()
        kwargs = {"full": args.full}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            for line in mod.run(**kwargs):
                print(line, flush=True)
            print(f"# suite {name}: {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"# suite {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
