"""Benchmark harness: one module per paper table/figure.

  bench_add         -> Fig. 3(a)/(b) + Table 1  (add/sub strategies)
  bench_mul         -> Table 4 + Fig. 3(d)      (multiplication routines)
  bench_div         -> beyond-paper             (division subsystem)
  bench_breakdown   -> Tables 1 & 3             (phase-wise attribution)
  bench_gmp         -> Fig. 4                   (GMPbench-style end-to-end)
  bench_crypto      -> Fig. 5 + latency CDFs    (OpenSSL-speed-style)
  bench_exact_accum -> beyond-paper             (exact grad reduction cost)
  bench_roofline    -> EXPERIMENTS.md SSRoofline (TPU terms from the dry-run)

Prints ``name,us_per_call,derived`` CSV.  ``--full`` widens the operand
grid (slower); ``--smoke`` shrinks suites that support it to tiny sizes
and 1-2 reps (the CI bitrot guard).  Individual suites:
``python -m benchmarks.bench_add``.

Perf trajectory across PRs: suites that support it (add, mul, div, and
crypto's modexp section) also produce machine-readable records.
``--json-out DIR`` writes/merges them into DIR/BENCH_<suite>.json
(keyed by op/bits/batch/backend, so smoke and full runs coexist in one
file; the crypto suite's records land in BENCH_modexp.json, see
SUITE_BASELINE); ``--check-baseline`` compares the fresh records
against the committed benchmarks/BENCH_<suite>.json and fails if any
Pallas backend's speedup-vs-jnp regressed by more than
REGRESS_TOLERANCE (the CI perf gate).

The committed smoke-key baselines are conservative FLOORS, not point
estimates: interpret-mode speedup ratios swing 1.5-3x run-to-run on
loaded CPU runners (measured repeatedly across PRs -- e.g. the 512-bit
fused-modexp ratio has been observed anywhere from 0.72x to 1.79x in
back-to-back runs of the same commit), so a floor set near a single
measurement is a coin-flip gate.  Policy: commit floors at ~0.5x of a
representative measured ratio, low enough that only a STRUCTURAL
regression (the fused path no longer decisively beating the jnp
composition) trips them, and rely on the batch-512 rows to record the
measured trajectory at full precision.  To keep regressions diagnosable
from CI logs alone, ``--check-baseline`` prints a ``# perf-gate:`` line
for EVERY gated key showing the fresh measurement, the committed floor,
and the margin between them -- a shrinking margin across PRs is the
early warning; the hard failure only fires below the floor.
"""
import argparse
import inspect
import json
import os
import sys
import time
import traceback

REGRESS_TOLERANCE = 0.20          # fail if speedup drops > 20% vs baseline
BASELINE_DIR = os.path.dirname(os.path.abspath(__file__))

# The crypto suite's machine-readable records are all modexp rows; its
# baseline lives under the op name so the file says what it gates.
SUITE_BASELINE = {"crypto": "modexp"}


def _key(rec):
    return (rec["op"], rec["bits"], rec["batch"], rec["backend"])


def _baseline_path(suite: str, out_dir: str | None = None) -> str:
    name = SUITE_BASELINE.get(suite, suite)
    return os.path.join(out_dir or BASELINE_DIR, f"BENCH_{name}.json")


def write_json(suite: str, records: list, out_dir: str) -> str:
    """Merge records into DIR/BENCH_<suite>.json (new keys win)."""
    os.makedirs(out_dir, exist_ok=True)
    path = _baseline_path(suite, out_dir)
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            for rec in json.load(f)["records"]:
                merged[_key(rec)] = rec
    for rec in records:
        merged[_key(rec)] = rec
    payload = {
        "schema": ("op,bits,batch,backend,ns_per_op,speedup_vs_jnp"
                   "[,perf_gate{baseline,floor,headroom}]"),
        "records": sorted(merged.values(),
                          key=lambda r: (r["op"], r["bits"], r["batch"],
                                         r["backend"])),
    }
    try:
        # snapshot the arithmetic cache counters alongside the records:
        # a cold operand cache in a CI artifact for a fixed-operand
        # suite is the reuse-regression signal (see api.cache_stats)
        from repro import api
        payload["cache_stats"] = api.cache_stats()
    except Exception:  # noqa: BLE001 - records still land without it
        pass
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path


def check_baseline(suite: str, records: list,
                   tolerance: float = REGRESS_TOLERANCE,
                   margins: list[str] | None = None,
                   infos: list[str] | None = None) -> list[str]:
    """Regression messages for Pallas backends vs the committed baseline.

    Compares the machine-independent speedup-vs-jnp ratio (both sides of
    the ratio are measured in the same run, so a slow CI machine cancels
    out); only keys present in both sets are judged.  The gate covers
    the multiply pipeline at kernel-sized operands (op "mul", >= 512
    bits, including the huge-operand "ntt" tier), the division kernel
    (op "div", >= 256 bits: the schoolbook kernel and the fixed-divisor
    "recip_cached" reciprocal path riding the prepared-operand NTT
    cache), the fused windowed modexp ladders (op "modexp", >= 512 bits
    -- the Montgomery fused kernel, the bit-serial composition it must
    keep beating, and the Barrett "barrett_fused" kernel vs its jnp
    composition), and the serving engine's batched-vs-naive throughput
    ratio (op "serve", backend "engine", see bench_serve): smaller
    micro rows and the add strategy sweep are recorded for the
    trajectory but their per-call times are too small for
    run-to-run-stable ratios.

    ``margins``, when given, collects one human-readable line per GATED
    key -- measured ratio, committed floor, and headroom -- so CI logs
    show how close every key sits to its floor even when nothing fails
    (the deflake contract: floors sit at ~0.5x of measured ratios, see
    the module docstring; a margin trending toward 0 is the signal to
    investigate before the hard gate fires).

    ``infos``, when given, collects one line per op-eligible row the
    gate filters SKIP (trajectory-only rows: below min_bits, a
    non-gated backend, or a key with no committed floor) so the CI log
    still shows their measured ratios -- headroom you can read without
    promoting the row to a hard gate.
    """
    path = _baseline_path(suite)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        baseline = {_key(r): r for r in json.load(f)["records"]}
    problems = []
    min_bits = {"mul": 512, "div": 256, "modexp": 512, "serve": 256}

    def gated(rec) -> bool:
        if rec["op"] not in min_bits or rec["bits"] < min_bits[rec["op"]]:
            return False
        if rec["op"] == "div":
            # schoolbook kernel + the fixed-divisor cached-reciprocal path
            return rec["backend"] in ("schoolbook", "recip_cached")
        if rec["op"] == "serve":
            # gate the headline engine-vs-cold-naive throughput ratio;
            # engine_vs_warm and naive rows are trajectory-only
            return rec["backend"] == "engine"
        return ("pallas" in rec["backend"] or "kernel" in rec["backend"]
                or rec["backend"] in ("ntt", "barrett_fused"))

    for rec in records:
        if not rec.get("speedup_vs_jnp"):
            continue
        base = baseline.get(_key(rec))
        if not gated(rec) or not base or not base.get("speedup_vs_jnp"):
            if infos is not None and rec["op"] in min_bits \
                    and rec["speedup_vs_jnp"] != 1.0:
                committed = (f"committed {base['speedup_vs_jnp']:.2f}x"
                             if base and base.get("speedup_vs_jnp")
                             else "no committed floor")
                infos.append(
                    f"{suite}:{'/'.join(map(str, _key(rec)))} measured "
                    f"{rec['speedup_vs_jnp']:.2f}x ({committed}; "
                    f"trajectory row, ungated)")
            continue
        floor = base["speedup_vs_jnp"] * (1.0 - tolerance)
        # annotate the record itself so --json-out artifacts carry the
        # gate verdict (floor + headroom) next to the measurement
        rec["perf_gate"] = {
            "baseline": base["speedup_vs_jnp"], "floor": round(floor, 4),
            "headroom": round(rec["speedup_vs_jnp"] / floor - 1.0, 4),
        }
        if margins is not None:
            margins.append(
                f"{suite}:{'/'.join(map(str, _key(rec)))} measured "
                f"{rec['speedup_vs_jnp']:.2f}x vs floor {floor:.2f}x "
                f"(headroom {rec['speedup_vs_jnp'] / floor - 1.0:+.0%})")
        if rec["speedup_vs_jnp"] < floor:
            problems.append(
                f"{suite}:{'/'.join(map(str, _key(rec)))} speedup "
                f"{rec['speedup_vs_jnp']:.2f}x < {floor:.2f}x "
                f"(baseline {base['speedup_vs_jnp']:.2f}x - {tolerance:.0%})")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (e.g. add,mul)")
    ap.add_argument("--json-out", default=None, metavar="DIR",
                    help="write/merge BENCH_<suite>.json records here")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail if a Pallas backend regressed >20%% vs the "
                         "committed BENCH_<suite>.json speedup baseline")
    args = ap.parse_args()

    from benchmarks import (bench_add, bench_breakdown, bench_crypto,
                            bench_div, bench_exact_accum, bench_gmp,
                            bench_mul, bench_roofline, bench_serve)
    suites = {
        "add": bench_add, "mul": bench_mul, "div": bench_div,
        "breakdown": bench_breakdown, "gmp": bench_gmp,
        "crypto": bench_crypto, "exact_accum": bench_exact_accum,
        "roofline": bench_roofline, "serve": bench_serve,
    }
    pick = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failures = 0
    regressions: list[str] = []
    for name in pick:
        mod = suites[name]
        t0 = time.time()
        sig = inspect.signature(mod.run).parameters
        kwargs = {"full": args.full}
        if args.smoke and "smoke" in sig:
            kwargs["smoke"] = True
        records: list = []
        if "records" in sig:
            kwargs["records"] = records
        try:
            for line in mod.run(**kwargs):
                print(line, flush=True)
            print(f"# suite {name}: {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"# suite {name} FAILED:", flush=True)
            traceback.print_exc()
            continue
        # check BEFORE writing: --json-out pointed at the baseline dir
        # must not overwrite the baseline the check compares against.
        # --json-out alone still runs the comparison (problems
        # discarded) so the written records carry perf_gate headroom.
        if records and (args.check_baseline or args.json_out):
            margins: list[str] = []
            infos: list[str] = []
            problems = check_baseline(name, records,
                                      margins=margins, infos=infos)
            if args.check_baseline:
                regressions.extend(problems)
                for line in margins:
                    print(f"# perf-gate: {line}", flush=True)
                for line in infos:
                    print(f"# info: {line}", flush=True)
        if records and args.json_out:
            path = write_json(name, records, args.json_out)
            print(f"# wrote {path} ({len(records)} records)", flush=True)
    from repro.kernels.common import autotune
    if autotune.enabled() and autotune.cache_summary():
        print(f"# autotuned tiles: {autotune.cache_summary()}", flush=True)
    for msg in regressions:
        print(f"# PERF REGRESSION: {msg}", flush=True)
    if failures or regressions:
        sys.exit(1)


if __name__ == "__main__":
    main()
