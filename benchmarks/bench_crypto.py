"""Paper Fig. 5 + appendix latency CDFs (OpenSSL speed): batched RSA
sign/verify and DH-style fixed-base modexp throughput + latency
percentiles across key sizes, reported head-to-head for the jnp and
pallas (fused VMEM-resident Montgomery kernel) backends.

The modexp section emits machine-readable records (op=modexp; see
run.py --json-out / --check-baseline) comparing three ladder
structures over per-lane full-width exponents:

  * ``jnp``              windowed k-ary ladder, jnp Montgomery multiply
                         (the speedup denominator),
  * ``pallas_bitserial`` the PR-3 structure: two fused mont-mul kernel
                         launches per exponent bit (rebuilt here from
                         dot_mont_mul as a measurement baseline -- the
                         bit-serial driver itself is gone from src),
  * ``pallas_fused``     the fused full-ladder windowed kernel: ONE
                         launch per modexp, table VMEM-resident.

Two satellite sections ride the same record format: the EVEN-modulus
head-to-head (``barrett`` jnp composition vs the ``barrett_fused``
single-launch Barrett ladder -- the moduli Montgomery cannot serve)
and the sub-batch packed ladder (batch 4 < the tile minimum: the
dispatcher pads lanes and fuses anyway, recorded as ``pallas_packed``
vs ``jnp``).

The committed benchmarks/BENCH_modexp.json floors gate pallas_fused,
barrett_fused, and pallas_packed in CI (conservative floors, not point
estimates: interpret-mode ratios swing 1.5-3x on loaded CPU runners).

``--smoke`` (or run(smoke=True)) shrinks to one tiny key and 2 reps so
CI can exercise the full code path in seconds (the bit-serial baseline
is skipped there: 2 launches x nbits is exactly the cost the fused
ladder deletes, and smoke wall-clock matters).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core import modular as MOD
from repro.core import rsa as RSA
from benchmarks.util import row, time_fn, record

BACKENDS = ("jnp", "pallas")


def _bitserial_pallas_mod_exp(base, eb, ctx):
    """The PR-3 bit-serial ladder structure, composed from the fused
    mont-mul kernel: square + multiply = two kernel launches per
    exponent bit, result selected by the bit.  Kept ONLY as the
    benchmark baseline the fused windowed ladder is gated against."""
    x = MOD.to_mont(jnp.asarray(base, jnp.uint32), ctx, backend="pallas")
    res0 = jnp.broadcast_to(
        jnp.asarray(ctx.one_digits, jnp.uint32), x.shape)
    eb = jnp.asarray(eb, jnp.uint32)
    eb_t = jnp.moveaxis(
        jnp.broadcast_to(eb, x.shape[:-1] + (eb.shape[-1],)), -1, 0)

    def step(res, bit):
        sq = MOD.mont_mul(res, res, ctx, backend="pallas")
        mul = MOD.mont_mul(sq, x, ctx, backend="pallas")
        return jnp.where((bit == 1)[..., None], mul, sq), None

    res, _ = jax.lax.scan(step, res0, eb_t)
    return MOD.from_mont(res, ctx, backend="pallas")


def _modexp_records(out, records, sizes, batch, iters, with_bitserial):
    """Per-lane full-width-exponent modexp: the throughput workload the
    batched-exponent fused ladder exists for."""
    rng = np.random.default_rng(23)
    for nbits in sizes:
        n = L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1)) | 1
        ctx = MOD.mont_setup(n, nbits)
        xs = [v % n for v in L.random_bigints(rng, batch, nbits)]
        md = jnp.asarray(np.stack(
            [L.int_to_limbs(x, ctx.m, 16) for x in xs]))
        eb = jnp.asarray(np.stack(
            [MOD.exp_bits_msb(int(e) | (1 << (nbits - 1)) | 1, nbits)
             for e in L.random_bigints(rng, batch, nbits)]))
        fns = {
            "jnp": jax.jit(
                lambda b, e, c=ctx: MOD.mod_exp(b, e, c, backend="jnp")),
            "pallas_fused": jax.jit(
                lambda b, e, c=ctx: MOD.mod_exp(b, e, c, backend="pallas")),
        }
        if with_bitserial and nbits <= 1024:
            # 2 launches/bit: beyond 1024 bits the baseline alone would
            # dominate the suite's wall-clock (which is the point)
            fns["pallas_bitserial"] = jax.jit(
                lambda b, e, c=ctx: _bitserial_pallas_mod_exp(b, e, c))
        t_jnp = None
        for be, fn in fns.items():
            t = time_fn(fn, md, eb, iters=iters, warmup=1)
            if be == "jnp":
                t_jnp = t
            record(records, op="modexp", bits=nbits, batch=batch,
                   backend=be, seconds_per_call=t, baseline_seconds=t_jnp)
            out.append(row(f"crypto/modexp{nbits}/{be}", t / batch,
                           f"ops_s={batch / t:.1f} "
                           f"speedup_vs_jnp={t_jnp / t:.2f}x"))


def _barrett_records(out, records, sizes, batch, iters):
    """EVEN-modulus modexp: Montgomery is unavailable (n must be odd),
    so the contest is the jnp Barrett composition vs the fused Barrett
    ladder kernel (one launch, n/mu as runtime rows)."""
    rng = np.random.default_rng(41)
    for nbits in sizes:
        n = (L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1))) & ~1
        ctx = MOD.mod_setup(n, nbits)
        xs = [v % n for v in L.random_bigints(rng, batch, nbits)]
        md = jnp.asarray(np.stack(
            [L.int_to_limbs(x, ctx.m, 16) for x in xs]))
        eb = jnp.asarray(np.stack(
            [MOD.exp_bits_msb(int(e) | (1 << (nbits - 1)) | 1, nbits)
             for e in L.random_bigints(rng, batch, nbits)]))
        t_jnp = None
        for be in ("barrett", "barrett_fused"):
            fn = jax.jit(
                lambda b, e, c=ctx, k=be: MOD.mod_exp(b, e, c, backend=k))
            t = time_fn(fn, md, eb, iters=iters, warmup=1)
            if be == "barrett":
                t_jnp = t
            record(records, op="modexp", bits=nbits, batch=batch,
                   backend=be, seconds_per_call=t, baseline_seconds=t_jnp)
            out.append(row(f"crypto/modexp{nbits}even/{be}", t / batch,
                           f"speedup_vs_jnp={t_jnp / t:.2f}x"))


def _packed_records(out, records, sizes, batch, iters):
    """Sub-batch lane packing: batches below the tile minimum pad up and
    still take the fused ladder (dispatch's packed_min_batch floor);
    this times that padded fused launch against the jnp ladder at the
    same tiny batch, so CI notices if padding ever makes the fused
    route a de-optimization."""
    rng = np.random.default_rng(43)
    for nbits in sizes:
        n = L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1)) | 1
        ctx = MOD.mont_setup(n, nbits)
        xs = [v % n for v in L.random_bigints(rng, batch, nbits)]
        md = jnp.asarray(np.stack(
            [L.int_to_limbs(x, ctx.m, 16) for x in xs]))
        eb = jnp.asarray(np.stack(
            [MOD.exp_bits_msb(int(e) | (1 << (nbits - 1)) | 1, nbits)
             for e in L.random_bigints(rng, batch, nbits)]))
        t_jnp = None
        for be, backend in (("jnp", "jnp"), ("pallas_packed", "pallas")):
            fn = jax.jit(
                lambda b, e, c=ctx, k=backend: MOD.mod_exp(b, e, c,
                                                           backend=k))
            t = time_fn(fn, md, eb, iters=iters, warmup=1)
            if be == "jnp":
                t_jnp = t
            record(records, op="modexp", bits=nbits, batch=batch,
                   backend=be, seconds_per_call=t, baseline_seconds=t_jnp)
            out.append(row(f"crypto/modexp{nbits}b{batch}/{be}", t / batch,
                           f"speedup_vs_jnp={t_jnp / t:.2f}x"))


def _latency_percentiles(fn, arg, iters=12):
    fn(arg).block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(arg).block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts = np.array(ts)
    return (np.percentile(ts, 50), np.percentile(ts, 95))


def run(full: bool = False, smoke: bool = False, records: list | None = None):
    out = []
    if smoke:
        sizes, batch, iters = (128,), 4, 2
        mx_sizes, mx_batch, mx_iters, bitserial = (512,), 64, 3, False
    elif full:
        sizes, batch, iters = (256, 512, 1024), 32, 12
        mx_sizes, mx_batch, mx_iters, bitserial = (512, 1024, 2048), 64, 3, True
    else:
        sizes, batch, iters = (256, 512), 32, 12
        mx_sizes, mx_batch, mx_iters, bitserial = (512, 1024), 64, 3, True
    if records is not None or not smoke:
        # In smoke mode the modexp section only matters for the gated
        # records; CI's standalone `bench_crypto --smoke` step (records
        # is None) already ran it via benchmarks.run -- skip the
        # duplicate timing, it is the slowest part of the smoke suite.
        _modexp_records(out, records, mx_sizes, mx_batch, mx_iters, bitserial)
        _barrett_records(out, records, mx_sizes, mx_batch, mx_iters)
        _packed_records(out, records, mx_sizes, 4, mx_iters)
    for bits in sizes:
        key = RSA.generate_key(bits=bits, seed=bits)
        msgs = [RSA.digest_int(f"m{i}".encode(), bits) for i in range(batch)]
        md = RSA.messages_to_digits(msgs, key)
        t_full = None
        for be in BACKENDS:
            sign = jax.jit(lambda x, k=key, b=be: RSA.sign(x, k, backend=b))
            verify = jax.jit(lambda x, k=key, b=be: RSA.verify(x, k, backend=b))
            p50, p95 = _latency_percentiles(sign, md, iters)
            if be == "jnp":              # the default backend: reused as the
                t_full = p50             # decrypt/full baseline below
            out.append(row(f"crypto/rsa{bits}/sign/{be}", p50 / batch,
                           f"p50_ms={p50 * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                           f"ops_s={batch / p50:.1f}"))
            sigs = sign(md)
            p50, p95 = _latency_percentiles(verify, sigs, iters)
            out.append(row(f"crypto/rsa{bits}/verify/{be}", p50 / batch,
                           f"p50_ms={p50 * 1e3:.1f} ops_s={batch / p50:.1f}"))
        # decrypt: full-width ladder (== sign, already timed above) vs
        # the CRT path (two half-size modexps + divmod-based Garner
        # recombination)
        dec_crt = jax.jit(lambda x, k=key: RSA.decrypt_crt(x, k))
        t_crt, p95 = _latency_percentiles(dec_crt, md, iters)
        out.append(row(f"crypto/rsa{bits}/decrypt/crt", t_crt / batch,
                       f"p50_ms={t_crt * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                       f"speedup_vs_full={t_full / t_crt:.2f}x"))

    # FFDH-style: fixed generator g=2, random exponents, odd prime-sized p
    rng = np.random.default_rng(7)
    nbits = 128 if smoke else 512
    ebits = 64 if smoke else 256
    p = L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = MOD.mont_setup(p, nbits)
    g = jnp.asarray(np.stack([L.int_to_limbs(2, ctx.m, 16)] * batch))
    exps = np.stack([MOD.exp_bits_msb(e | (1 << (ebits - 1)), ebits)
                     for e in L.random_bigints(rng, batch, ebits)])
    for be in BACKENDS:
        derive = jax.jit(
            lambda b, e, k=be: MOD.mod_exp(b, e, ctx, backend=k))
        p50, p95 = _latency_percentiles(
            lambda a: derive(a, jnp.asarray(exps)), g, iters)
        out.append(row(f"crypto/ffdh{nbits}/derive/{be}", p50 / batch,
                       f"p50_ms={p50 * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                       f"ops_s={batch / p50:.1f}"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full, smoke=args.smoke):
        print(r)
