"""Paper Fig. 5 + appendix latency CDFs (OpenSSL speed): batched RSA
sign/verify and DH-style fixed-base modexp throughput + latency
percentiles across key sizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core import modular as MOD
from repro.core import rsa as RSA
from benchmarks.util import row


def _latency_percentiles(fn, arg, iters=12):
    fn(arg).block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(arg).block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts = np.array(ts)
    return (np.percentile(ts, 50), np.percentile(ts, 95))


def run(full: bool = False):
    out = []
    sizes = (256, 512) if not full else (256, 512, 1024)
    batch = 32
    for bits in sizes:
        key = RSA.generate_key(bits=bits, seed=bits)
        msgs = [RSA.digest_int(f"m{i}".encode(), bits) for i in range(batch)]
        md = RSA.messages_to_digits(msgs, key)
        sign = jax.jit(lambda x, k=key: RSA.sign(x, k))
        verify = jax.jit(lambda x, k=key: RSA.verify(x, k))
        p50, p95 = _latency_percentiles(sign, md)
        out.append(row(f"crypto/rsa{bits}/sign", p50 / batch,
                       f"p50_ms={p50 * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                       f"ops_s={batch / p50:.1f}"))
        sigs = sign(md)
        p50, p95 = _latency_percentiles(verify, sigs)
        out.append(row(f"crypto/rsa{bits}/verify", p50 / batch,
                       f"p50_ms={p50 * 1e3:.1f} ops_s={batch / p50:.1f}"))

    # FFDH-style: fixed generator g=2, random 256-bit exponents, 512-bit p
    rng = np.random.default_rng(7)
    nbits = 512
    p = L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = MOD.mont_setup(p, nbits)
    g = jnp.asarray(np.stack([L.int_to_limbs(2, ctx.m, 16)] * batch))
    exps = np.stack([MOD.exp_bits_msb(e | (1 << 255), 256)
                     for e in L.random_bigints(rng, batch, 256)])
    derive = jax.jit(lambda b, e: MOD.mod_exp(b, e, ctx))
    p50, p95 = _latency_percentiles(lambda a: derive(a, jnp.asarray(exps)), g)
    out.append(row(f"crypto/ffdh{nbits}/derive", p50 / batch,
                   f"p50_ms={p50 * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                   f"ops_s={batch / p50:.1f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
