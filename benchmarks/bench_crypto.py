"""Paper Fig. 5 + appendix latency CDFs (OpenSSL speed): batched RSA
sign/verify and DH-style fixed-base modexp throughput + latency
percentiles across key sizes, reported head-to-head for the jnp and
pallas (fused VMEM-resident Montgomery kernel) backends.

``--smoke`` (or run(smoke=True)) shrinks to one tiny key and 2 reps so
CI can exercise the full code path in seconds.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core import modular as MOD
from repro.core import rsa as RSA
from benchmarks.util import row

BACKENDS = ("jnp", "pallas")


def _latency_percentiles(fn, arg, iters=12):
    fn(arg).block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(arg).block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts = np.array(ts)
    return (np.percentile(ts, 50), np.percentile(ts, 95))


def run(full: bool = False, smoke: bool = False):
    out = []
    if smoke:
        sizes, batch, iters = (128,), 4, 2
    elif full:
        sizes, batch, iters = (256, 512, 1024), 32, 12
    else:
        sizes, batch, iters = (256, 512), 32, 12
    for bits in sizes:
        key = RSA.generate_key(bits=bits, seed=bits)
        msgs = [RSA.digest_int(f"m{i}".encode(), bits) for i in range(batch)]
        md = RSA.messages_to_digits(msgs, key)
        t_full = None
        for be in BACKENDS:
            sign = jax.jit(lambda x, k=key, b=be: RSA.sign(x, k, backend=b))
            verify = jax.jit(lambda x, k=key, b=be: RSA.verify(x, k, backend=b))
            p50, p95 = _latency_percentiles(sign, md, iters)
            if be == "jnp":              # the default backend: reused as the
                t_full = p50             # decrypt/full baseline below
            out.append(row(f"crypto/rsa{bits}/sign/{be}", p50 / batch,
                           f"p50_ms={p50 * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                           f"ops_s={batch / p50:.1f}"))
            sigs = sign(md)
            p50, p95 = _latency_percentiles(verify, sigs, iters)
            out.append(row(f"crypto/rsa{bits}/verify/{be}", p50 / batch,
                           f"p50_ms={p50 * 1e3:.1f} ops_s={batch / p50:.1f}"))
        # decrypt: full-width ladder (== sign, already timed above) vs
        # the CRT path (two half-size modexps + divmod-based Garner
        # recombination)
        dec_crt = jax.jit(lambda x, k=key: RSA.decrypt_crt(x, k))
        t_crt, p95 = _latency_percentiles(dec_crt, md, iters)
        out.append(row(f"crypto/rsa{bits}/decrypt/crt", t_crt / batch,
                       f"p50_ms={t_crt * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                       f"speedup_vs_full={t_full / t_crt:.2f}x"))

    # FFDH-style: fixed generator g=2, random exponents, odd prime-sized p
    rng = np.random.default_rng(7)
    nbits = 128 if smoke else 512
    ebits = 64 if smoke else 256
    p = L.random_bigints(rng, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = MOD.mont_setup(p, nbits)
    g = jnp.asarray(np.stack([L.int_to_limbs(2, ctx.m, 16)] * batch))
    exps = np.stack([MOD.exp_bits_msb(e | (1 << (ebits - 1)), ebits)
                     for e in L.random_bigints(rng, batch, ebits)])
    for be in BACKENDS:
        derive = jax.jit(
            lambda b, e, k=be: MOD.mod_exp(b, e, ctx, backend=k))
        p50, p95 = _latency_percentiles(
            lambda a: derive(a, jnp.asarray(exps)), g, iters)
        out.append(row(f"crypto/ffdh{nbits}/derive/{be}", p50 / batch,
                       f"p50_ms={p50 * 1e3:.1f} p95_ms={p95 * 1e3:.1f} "
                       f"ops_s={batch / p50:.1f}"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full, smoke=args.smoke):
        print(r)
