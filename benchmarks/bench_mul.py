"""Paper Table 4 + Fig. 3(d): multiplication routines.

256-bit base case (the integration unit) across: DoT VnC (jnp + Pallas
kernel), MXU Toeplitz path, shared-accumulator schoolbook (Gueron-style
RAW chain), and Karatsuba-over-DoT for larger operands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.mul as M
from repro.core import limbs as L
from repro.kernels.dot_mul import ops as mul_kernel_ops
from benchmarks.util import hlo_ops, row, time_fn

BATCH = 512


def _limbs(rng, nbits, batch):
    m = nbits // 32
    xs = L.random_bigints(rng, batch, nbits)
    ys = L.random_bigints(rng, batch, nbits)
    return (jnp.asarray(L.ints_to_batch(xs, m)),
            jnp.asarray(L.ints_to_batch(ys, m)))


def run(full: bool = False):
    rng = np.random.default_rng(1)
    out = []

    # --- Table 4: 256-bit base case ---
    a, b = _limbs(rng, 256, BATCH)
    variants = {
        "dot_vnc": lambda x, y: M.mul_limbs32(x, y, method="dot"),
        "dot_kernel": lambda x, y: mul_kernel_ops.dot_mul_limbs32(x, y),
        "mxu_toeplitz": lambda x, y: M.mul_limbs32(x, y, method="mxu"),
        "schoolbook_raw": lambda x, y: M.mul_limbs32(x, y, method="schoolbook"),
    }
    base_t = None
    for name, f in variants.items():
        fn = jax.jit(f)
        t = time_fn(fn, a, b, iters=10)
        ops = hlo_ops(f, a, b)
        if name == "schoolbook_raw":
            base_t = t
        out.append(row(f"mul256/{name}", t / BATCH, f"ops={ops}"))
    # speedup vs the shared-accumulator baseline (paper: 2.31x vs IFMA)
    t_dot = time_fn(jax.jit(variants["dot_vnc"]), a, b, iters=10)
    out.append(row("mul256/speedup_dot_vs_schoolbook", 0.0,
                   f"{base_t / t_dot:.2f}x"))

    # --- Fig 3(d): larger operands through Karatsuba ---
    sizes = (512, 1024, 2048, 4096) if full else (1024, 4096)
    for nbits in sizes:
        a, b = _limbs(rng, nbits, 64)
        for method in ("karatsuba", "schoolbook"):
            fn = jax.jit(lambda x, y, mm=method: M.mul_limbs32(x, y, method=mm))
            t = time_fn(fn, a, b, iters=5)
            out.append(row(f"mul/{nbits}b/{method}", t / 64, ""))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
