"""Paper Table 4 + Fig. 3(d): multiplication routines.

256-bit base case (the integration unit) across: DoT VnC (jnp + Pallas
kernel), MXU Toeplitz (jnp + Pallas kernel), shared-accumulator
schoolbook (Gueron-style RAW chain); then the large-operand grid where
the unified pipeline's backends compete head-to-head -- the jnp
Karatsuba composition (per-level carry resolves) vs the fused
Karatsuba-over-VnC kernel (one launch, one resolve); then the
huge-operand NTT/CRT tier (8192..65536 bits, one fused transform launch
per CRT prime) against the jnp Karatsuba fallback it replaces.

Emits machine-readable records (op, bits, batch, backend, ns/op,
speedup-vs-jnp) when driven through benchmarks/run.py --json-out; the
committed benchmarks/BENCH_mul.json baseline is the regression gate for
`run.py --check-baseline` in CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.mul as M
from repro.core import limbs as L
from repro.kernels.dot_mul import ops as mul_kernel_ops
from repro.kernels.mxu_mul import ops as mxu_kernel_ops
from benchmarks.util import hlo_ops, record, row, time_fn

BATCH = 512


def _limbs(rng, nbits, batch):
    m = nbits // 32
    xs = L.random_bigints(rng, batch, nbits)
    ys = L.random_bigints(rng, batch, nbits)
    return (jnp.asarray(L.ints_to_batch(xs, m)),
            jnp.asarray(L.ints_to_batch(ys, m)))


def run(full: bool = False, smoke: bool = False, records=None):
    rng = np.random.default_rng(1)
    out = []
    # smoke trims the size grid and halves the batch -- but keeps both
    # large enough (batch 256, 8 reps) that the medians feeding the
    # --check-baseline perf gate stay meaningful: sub-100us calls at
    # batch<=64 produce speedup ratios that swing ~2x run-to-run on a
    # loaded runner (measured), which no sane tolerance survives.  The
    # (op, bits, batch) baseline keys in BENCH_mul.json must match these
    # values.
    batch = 256 if smoke else BATCH
    iters = 8 if smoke else 10

    # --- Table 4: 256-bit base case ---
    a, b = _limbs(rng, 256, batch)
    variants = {
        "dot_vnc": lambda x, y: M.mul_limbs32(x, y, method="dot"),
        "dot_kernel": lambda x, y: mul_kernel_ops.dot_mul_limbs32(x, y),
        "mxu_toeplitz": lambda x, y: M.mul_limbs32(x, y, method="mxu"),
        "mxu_kernel": lambda x, y: mxu_kernel_ops.mxu_mul_limbs32(x, y),
        "schoolbook_raw": lambda x, y: M.mul_limbs32(x, y, method="schoolbook"),
    }
    times = {}
    for name, f in variants.items():
        fn = jax.jit(f)
        t = time_fn(fn, a, b, iters=iters)
        times[name] = t
        ops = hlo_ops(f, a, b)
        out.append(row(f"mul256/{name}", t / batch, f"ops={ops}"))
        record(records, op="mul", bits=256, batch=batch, backend=name,
               seconds_per_call=t, baseline_seconds=times["dot_vnc"])
    # speedup vs the shared-accumulator baseline (paper: 2.31x vs IFMA)
    out.append(row("mul256/speedup_dot_vs_schoolbook", 0.0,
                   f"{times['schoolbook_raw'] / times['dot_vnc']:.2f}x"))

    # --- Fig 3(d) / the unified pipeline: large operands ---
    if smoke:
        sizes = (512, 1024)
    elif full:
        sizes = (512, 1024, 2048, 4096)
    else:
        sizes = (1024, 2048)
    for nbits in sizes:
        a, b = _limbs(rng, nbits, batch)
        methods = ["karatsuba", "pallas_kara"]
        if nbits <= 512:
            methods.append("pallas")
        if full:
            methods.append("mxu")
        t_jnp = None
        for method in methods:
            fn = jax.jit(lambda x, y, mm=method: M.mul_limbs32(x, y, method=mm))
            # full rep count: these rows feed the --check-baseline gate
            t = time_fn(fn, a, b, iters=iters)
            if method == "karatsuba":
                t_jnp = t
            tag = "" if method == "karatsuba" else \
                f"speedup_vs_jnp={t_jnp / t:.2f}x"
            out.append(row(f"mul/{nbits}b/{method}", t / batch, tag))
            record(records, op="mul", bits=nbits, batch=batch, backend=method,
                   seconds_per_call=t, baseline_seconds=t_jnp)

    # --- the huge-operand NTT/CRT tier (kernels/ntt_mul) ---
    # The jnp Karatsuba composition is the dispatch fallback the NTT tier
    # replaces; its XLA compile is ~80s at 8192 bits and grows with the
    # recursion tree (minutes past 16K bits), so the head-to-head runs at
    # 8192 bits only and the wider rows record the NTT trajectory --
    # there IS no feasible jnp baseline to time up there, which is
    # precisely the point of the tier.
    ntt_batch = 16 if smoke else 32
    if smoke:
        ntt_sizes = (8192,)
    elif full:
        ntt_sizes = (8192, 16384, 65536)
    else:
        ntt_sizes = (8192, 16384)
    for nbits in ntt_sizes:
        a, b = _limbs(rng, nbits, ntt_batch)
        t_jnp = None
        if nbits == 8192:
            fn = jax.jit(lambda x, y: M.mul_limbs32(x, y, method="karatsuba"))
            t_jnp = time_fn(fn, a, b, iters=iters)
            out.append(row(f"mul/{nbits}b/karatsuba", t_jnp / ntt_batch))
            record(records, op="mul", bits=nbits, batch=ntt_batch,
                   backend="karatsuba", seconds_per_call=t_jnp,
                   baseline_seconds=t_jnp)
        fn = jax.jit(lambda x, y: M.mul_limbs32(x, y, method="ntt"))
        t = time_fn(fn, a, b, iters=iters)
        tag = (f"speedup_vs_jnp={t_jnp / t:.2f}x" if t_jnp
               else "ntt-only: jnp karatsuba compile infeasible here")
        out.append(row(f"mul/{nbits}b/ntt", t / ntt_batch, tag))
        record(records, op="mul", bits=nbits, batch=ntt_batch, backend="ntt",
               seconds_per_call=t, baseline_seconds=t_jnp)
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
