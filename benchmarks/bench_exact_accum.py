"""Beyond-paper feature benchmark: overhead of exact deferred-carry
gradient reduction vs plain f32 accumulation, at gradient-tree scale.

The interesting number is the encode+accumulate+resolve cost relative to
an f32 add of the same tensor -- this is what a replica pays per
microbatch for bitwise-reproducible elastic training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exact_accum as EA
from benchmarks.util import row, time_fn


def run(full: bool = False):
    out = []
    rng = np.random.default_rng(5)
    n = 1 << 20 if full else 1 << 18      # ~0.26M-1M gradient elements
    x = jnp.asarray(rng.standard_normal((n,)).astype(np.float32))
    acc = EA.encode(x)

    t_f32 = time_fn(jax.jit(lambda a, b: a + b), x, x)
    enc = jax.jit(EA.encode)
    t_enc = time_fn(enc, x)
    t_acc = time_fn(jax.jit(EA.accumulate), acc, acc)
    t_norm = time_fn(jax.jit(lambda d: EA.decode(EA.normalize(d))), acc)

    out.append(row("exact_accum/f32_add_baseline", t_f32, f"n={n}"))
    out.append(row("exact_accum/encode", t_enc,
                   f"overhead_vs_f32={t_enc / t_f32:.1f}x"))
    out.append(row("exact_accum/accumulate", t_acc,
                   f"overhead_vs_f32={t_acc / t_f32:.1f}x (deferred carries)"))
    out.append(row("exact_accum/resolve+decode", t_norm,
                   "amortized once per global batch"))
    per_mb = t_enc + t_acc
    out.append(row("exact_accum/per_microbatch_total", per_mb,
                   f"{per_mb / t_f32:.1f}x of one f32 add"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
