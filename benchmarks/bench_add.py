"""Paper Fig. 3(a)/(b) + Table 1: add/sub strategies across operand sizes.

Compares DoT against the prior-work dependency structures (sequential ADC
chain, naive SIMD ripple, full KSA, two-level KSA [y-cruncher], carry-
select [Ren et al.]) and the Pallas dot_add kernel, on random and
pathological operands, reporting wall time and HLO instruction counts.

Emits machine-readable records (op, bits, batch, backend, ns/op,
speedup-vs-jnp with the jnp DoT strategy as the baseline) when driven
through benchmarks/run.py --json-out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.add as A
from repro.core import limbs as L
from repro.kernels.dot_add import ops as add_kernel_ops
from benchmarks.util import hlo_ops, record, row, time_fn

SIZES = (512, 1024, 2048, 4096, 8192, 16384, 32768)
BATCH = 512
STRATEGIES = ("seq", "naive_simd", "ksa", "two_level_ksa", "carry_select", "dot")


def _operands(rng, nbits, batch, pathological=False):
    m = nbits // 32
    if pathological:
        pairs = L.pathological_pairs(nbits)
        reps = -(-batch // len(pairs))
        xs = [p[0] for p in pairs] * reps
        ys = [p[1] for p in pairs] * reps
        xs, ys = xs[:batch], ys[:batch]
    else:
        xs = L.random_bigints(rng, batch, nbits)
        ys = L.random_bigints(rng, batch, nbits)
    return (jnp.asarray(L.ints_to_batch(xs, m)),
            jnp.asarray(L.ints_to_batch(ys, m)))


def run(full: bool = False, smoke: bool = False, records=None):
    rng = np.random.default_rng(0)
    out = []
    if smoke:
        sizes, batch, iters = (512, 2048), 64, 3
    else:
        sizes, batch, iters = (SIZES if full else SIZES[::2]), BATCH, 10
    for nbits in sizes:
        a, b = _operands(rng, nbits, batch)
        ap, bp = _operands(rng, nbits, batch, pathological=True)
        strat_times = {}
        for strat in STRATEGIES:
            fn = jax.jit(lambda x, y, s=strat: A.ADD_STRATEGIES[s](x, y))
            t = time_fn(fn, a, b, iters=iters)
            tp = time_fn(fn, ap, bp, iters=max(2, iters // 2))
            ops = hlo_ops(lambda x, y, s=strat: A.ADD_STRATEGIES[s](x, y), a, b)
            strat_times[strat] = t
            out.append(row(f"add/{nbits}b/{strat}", t / batch,
                           f"speedup_vs_seq={strat_times['seq'] / t:.2f}x "
                           f"ops={ops} patho_us={tp / batch * 1e6:.2f}"))
        # the Pallas kernel riding the same records stream; jitted like
        # every strategy above so the recorded ratio compares kernels,
        # not Python wrapper overhead
        t_dot = strat_times["dot"]
        t_pal = time_fn(jax.jit(lambda x, y: add_kernel_ops.dot_add(x, y)),
                        a, b, iters=iters)
        out.append(row(f"add/{nbits}b/pallas", t_pal / batch,
                       f"speedup_vs_dot={t_dot / t_pal:.2f}x"))
        for strat, t in strat_times.items():
            record(records, op="add", bits=nbits, batch=batch, backend=strat,
                   seconds_per_call=t, baseline_seconds=t_dot)
        record(records, op="add", bits=nbits, batch=batch, backend="pallas",
               seconds_per_call=t_pal, baseline_seconds=t_dot)
    # subtraction spot check (paper reports symmetric results)
    for nbits in ((2048,) if not smoke else ()):
        a, b = _operands(rng, nbits, batch)
        for strat in ("seq", "dot"):
            fn = jax.jit(lambda x, y, s=strat: A.SUB_STRATEGIES[s](x, y))
            t = time_fn(fn, a, b, iters=iters)
            out.append(row(f"sub/{nbits}b/{strat}", t / batch, ""))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
