"""Paper Fig. 3(a)/(b) + Table 1: add/sub strategies across operand sizes.

Compares DoT against the prior-work dependency structures (sequential ADC
chain, naive SIMD ripple, full KSA, two-level KSA [y-cruncher], carry-
select [Ren et al.]) on random and pathological operands, reporting wall
time and HLO instruction counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.add as A
from repro.core import limbs as L
from benchmarks.util import hlo_ops, row, time_fn

SIZES = (512, 1024, 2048, 4096, 8192, 16384, 32768)
BATCH = 512
STRATEGIES = ("seq", "naive_simd", "ksa", "two_level_ksa", "carry_select", "dot")


def _operands(rng, nbits, batch, pathological=False):
    m = nbits // 32
    if pathological:
        pairs = L.pathological_pairs(nbits)
        reps = -(-batch // len(pairs))
        xs = [p[0] for p in pairs] * reps
        ys = [p[1] for p in pairs] * reps
        xs, ys = xs[:batch], ys[:batch]
    else:
        xs = L.random_bigints(rng, batch, nbits)
        ys = L.random_bigints(rng, batch, nbits)
    return (jnp.asarray(L.ints_to_batch(xs, m)),
            jnp.asarray(L.ints_to_batch(ys, m)))


def run(full: bool = False):
    rng = np.random.default_rng(0)
    out = []
    sizes = SIZES if full else SIZES[::2]
    for nbits in sizes:
        a, b = _operands(rng, nbits, BATCH)
        ap, bp = _operands(rng, nbits, BATCH, pathological=True)
        base_t = None
        for strat in STRATEGIES:
            fn = jax.jit(lambda x, y, s=strat: A.ADD_STRATEGIES[s](x, y))
            t = time_fn(fn, a, b, iters=10)
            tp = time_fn(fn, ap, bp, iters=5)
            ops = hlo_ops(lambda x, y, s=strat: A.ADD_STRATEGIES[s](x, y), a, b)
            if strat == "seq":
                base_t = t
            out.append(row(f"add/{nbits}b/{strat}", t / BATCH,
                           f"speedup_vs_seq={base_t / t:.2f}x ops={ops} "
                           f"patho_us={tp / BATCH * 1e6:.2f}"))
    # subtraction spot check (paper reports symmetric results)
    for nbits in (2048,):
        a, b = _operands(rng, nbits, BATCH)
        for strat in ("seq", "dot"):
            fn = jax.jit(lambda x, y, s=strat: A.SUB_STRATEGIES[s](x, y))
            t = time_fn(fn, a, b, iters=10)
            out.append(row(f"sub/{nbits}b/{strat}", t / BATCH, ""))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
