"""Paper Tables 1 & 3: phase-wise cost breakdown of DoT addition and
multiplication, plus the carry-to-add overhead ratio on random vs
pathological inputs.

Phase costs are measured by timing jitted PREFIXES of the algorithm
(P1; P1-2; P1-3; P1-4) and differencing -- the same attribution the
paper does with cycle counters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import limbs as L
from repro.core.add import _carries_ksa, _shift_up
from benchmarks.util import row, time_fn

U32 = jnp.uint32
MAX = jnp.uint32(0xFFFFFFFF)
BATCH = 1024
NBITS = 512   # paper Table 3: 512-bit addition, m=16 32-bit limbs


def _phase_fns():
    def p1(a, b):                       # load + add
        return a + b

    def p12(a, b):                      # + carry generation / alignment
        r = a + b
        c = (r < a).astype(U32)
        return r, _shift_up(c, jnp.zeros(a.shape[:-1], U32)), c[..., -1]

    def p123(a, b):                     # + carry add (fast path complete)
        r, ca, cout = p12(a, b)
        r2 = r + ca
        return r2, cout | (r2 < r)[..., -1].astype(U32)

    def p1234(a, b):                    # + unconditional Phase 4 (KSA)
        r = a + b
        g = (r < a).astype(U32)
        p = (r == MAX).astype(U32)
        c, cout = _carries_ksa(g, p, jnp.zeros(a.shape[:-1], U32))
        return r + c, cout

    return p1, p12, p123, p1234


def run(full: bool = False):
    rng = np.random.default_rng(2)
    m = NBITS // 32
    xs = L.random_bigints(rng, BATCH, NBITS)
    ys = L.random_bigints(rng, BATCH, NBITS)
    a = jnp.asarray(L.ints_to_batch(xs, m))
    b = jnp.asarray(L.ints_to_batch(ys, m))

    p1, p12, p123, p1234 = _phase_fns()
    t1 = time_fn(jax.jit(p1), a, b)
    t12 = time_fn(jax.jit(p12), a, b)
    t123 = time_fn(jax.jit(p123), a, b)
    t1234 = time_fn(jax.jit(p1234), a, b)

    total = t1234
    ph = {
        "p1_add": t1,
        "p2_carry_gen": max(t12 - t1, 0),
        "p3_carry_add": max(t123 - t12, 0),
        "p4_resolve": max(t1234 - t123, 0),
    }
    out = []
    for name, t in ph.items():
        out.append(row(f"breakdown/add512/{name}", t / BATCH,
                       f"pct={100 * t / total:.1f}"))
    carry = ph["p2_carry_gen"] + ph["p3_carry_add"] + ph["p4_resolve"]
    out.append(row("breakdown/add512/carry_to_add_ratio", 0.0,
                   f"{carry / max(ph['p1_add'], 1e-12):.2f} (paper DoT: 4.9)"))

    # Phase-4 trigger rate: random vs pathological (paper: never vs always)
    def trigger_rate(pairs):
        aa = jnp.asarray(L.ints_to_batch([p[0] for p in pairs], m))
        bb = jnp.asarray(L.ints_to_batch([p[1] for p in pairs], m))
        r = aa + bb
        c = (r < aa).astype(U32)
        ca = _shift_up(c, jnp.zeros(aa.shape[:-1], U32))
        r2 = r + ca
        casc = (r2 < r)[..., :-1].any(-1)
        return float(casc.mean())

    rnd_rate = trigger_rate(list(zip(xs, ys)))
    patho_rate = trigger_rate(L.pathological_pairs(NBITS))
    out.append(row("breakdown/add512/phase4_rate_random", 0.0, f"{rnd_rate:.2e}"))
    out.append(row("breakdown/add512/phase4_rate_pathological", 0.0,
                   f"{patho_rate:.2f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
