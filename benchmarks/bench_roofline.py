"""Roofline terms per (arch x shape) from the dry-run artifacts
(experiments/dryrun/*.json).  This is the TPU-performance benchmark: the
CPU container cannot measure wall-time MFU, so the three terms come from
the compiled artifacts (see launch/roofline.py for the methodology).
Emits one row per cell: name, dominant-term seconds, derived terms.
"""
from __future__ import annotations

import pathlib

from repro.launch import roofline as R
from benchmarks.util import row


def run(full: bool = False, dry_dir: str = "experiments/dryrun"):
    out = []
    out_dir = pathlib.Path(dry_dir)
    if not out_dir.exists():
        return [row("roofline/missing", 0.0,
                    "run `python -m repro.launch.dryrun --all` first")]
    seen = set()
    for p in sorted(out_dir.glob("*.single.base.json")):
        arch, shape = p.name.split(".")[:2]
        if (arch, shape) in seen:
            continue
        seen.add((arch, shape))
        c = R.corrected_cell(out_dir, arch, shape, "single")
        if not c:
            continue
        dom_s = max(c["t_compute"], c["t_memory"], c["t_collective"])
        out.append(row(
            f"roofline/{arch}/{shape}", dom_s,
            f"dominant={c['dominant']} compute={c['t_compute']:.3e} "
            f"memory={c['t_memory']:.3e} coll={c['t_collective']:.3e} "
            f"frac={c['roofline_fraction']:.2f} useful={c['useful_ratio']:.2f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
