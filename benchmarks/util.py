"""Benchmark helpers: median wall-time of jitted calls + HLO op counts.

CPU wall-clock here orders the ALGORITHM STRUCTURES (dependency depth,
op counts); absolute TPU performance comes from the dry-run roofline
(benchmarks/bench_roofline.py).  "ops" counts optimized-HLO instructions
-- the analogue of the paper's perf_event instruction counts.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median seconds per call of an already-jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def hlo_ops(fn, *args) -> int:
    """Instruction count of the optimized HLO module."""
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return sum(1 for line in txt.splitlines() if " = " in line)


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def record(records, *, op: str, bits: int, batch: int, backend: str,
           seconds_per_call: float, baseline_seconds: float | None) -> None:
    """Append one machine-readable benchmark record (see run.py --json-out).

    ``seconds_per_call`` covers the whole batch; ns/op is per batch
    element.  ``baseline_seconds`` is the jnp composition's time for the
    same (op, bits, batch) -- the speedup denominator tracked across PRs.
    No-op when records is None (suites run standalone).
    """
    if records is None:
        return
    records.append({
        "op": op,
        "bits": int(bits),
        "batch": int(batch),
        "backend": backend,
        "ns_per_op": round(seconds_per_call * 1e9 / max(1, batch), 1),
        "speedup_vs_jnp": (
            round(baseline_seconds / seconds_per_call, 3)
            if baseline_seconds else None),
    })
