"""Continuous-batching serving benchmark (beyond-paper): Poisson
request trace through serve/bignum_engine.BignumEngine vs the
one-request-at-a-time NaiveServer.

The trace mixes several moduli of DIFFERENT natural widths (1024 /
1008 / 992 / 976 bits by default -- think distinct DH groups / RSA
keys in one deployment).  Three replays of the same trace:

  * ``engine``         shape-bucketed, continuously batched, pre-warmed
                       on its finite modulus set (warming is the
                       engine's startup contract; its jit cache key
                       makes the compile set finite).  The benchmark
                       asserts ZERO retraces across the replay.
  * ``naive_cold``     one-at-a-time at natural shapes: every new
                       width/modulus retraces IN-TRACE -- the cost a
                       shape-following server actually pays on this
                       request mix (gated record: op=serve,
                       backend=engine, speedup = cold/engine).
  * ``naive_warm``     the same server replayed again, now fully
                       compiled: isolates the pure batching win
                       (recorded as backend=engine_vs_warm, ungated --
                       on a single CPU core batch-8 modexp gains are
                       modest; lane-parallel hardware is where the
                       fused ladder's batch regime pays).

Both sides run the jnp backend so the ratio measures serving structure
(batching + program caching), not backend choice: on this CPU the
Pallas ladder executes in interpret mode and would handicap whichever
side used it; on real TPU the engine's auto-dispatch hands kernel-sized
batches to the fused ladder.

The virtual-clock replay model (see bignum_engine.replay_trace) uses
real measured service wall-times on a Poisson arrival clock, so ops/s
and latency percentiles are reproducible run to run up to machine
speed; the gated quantity is a SAME-RUN ratio, so a slow CI machine
cancels out.  ``--smoke`` shrinks to 256-bit moduli and a short trace.

The committed benchmarks/BENCH_serve.json "engine" rows are
conservative FLOORS per the run.py deflake policy, far below measured
(observed ~644x at 256 bits / ~40x at 1024 bits, dominated by the
naive server's in-trace compiles; committed 40x / 8x): the gate should
only trip if the engine structurally loses its no-retrace or batching
advantage, not on compile-time noise.
"""
from __future__ import annotations

import argparse
import contextlib
import json

from benchmarks.util import record, row
from repro import obs
from repro.launch.serve_bignum import build_ops
from repro.obs import retrace as _retrace
from repro.serve.bignum_engine import (
    BignumEngine, NaiveServer, poisson_trace, replay_naive, replay_trace)
from repro.configs.dot_bignum import ServeConfig

BACKEND = "jnp"          # held equal on both sides; see module docstring


def _replay_point(out, records, *, bits, groups, n, rate, slots, seed):
    templates, warm = build_ops("mod_exp", bits, groups, seed)

    def trace():
        return poisson_trace(templates, n, rate, seed=seed)

    cfg = ServeConfig(slots=slots)
    engine = BignumEngine(cfg, backend=BACKEND)
    with obs.span(f"bench_serve/warm/{bits}", cat="trace",
                  buckets=len(warm)):
        for w in warm:
            engine.warm(**w)
    # zero-retrace gate, via the runtime alarm's metric rather than a
    # bench-internal assert: the engine's own _on_trace hook ticks
    # retraces_total on any post-warm jit cache miss (it ticks with
    # observability off too), so the benchmark gates on the same signal
    # CI reads from the metrics artifact
    retraces0 = _retrace.count("serve")
    with obs.span(f"bench_serve/engine/{bits}", cat="execute", n=n):
        eng = replay_trace(engine, trace())
    retraces = _retrace.count("serve") - retraces0
    if retraces:
        raise AssertionError(
            f"engine retraced {retraces}x across the mixed trace "
            f"(retraces_total metric; stats: {engine.stats})")

    naive = NaiveServer(backend=BACKEND)
    with obs.span(f"bench_serve/naive_cold/{bits}", cat="trace", n=n):
        cold = replay_naive(naive, trace())
    with obs.span(f"bench_serve/naive_warm/{bits}", cat="execute", n=n):
        warmed = replay_naive(naive, trace())   # same server, compiled

    st = engine.stats
    out.append(row(
        f"serve/poisson{bits}/engine", eng.makespan_s / n,
        f"ops_s={eng.ops_per_s:.1f} p50_ms={eng.p50_ms:.1f} "
        f"p99_ms={eng.p99_ms:.1f} batches={st.batches} "
        f"full={st.flush_full} deadline={st.flush_deadline} "
        f"padded={st.padded_lanes} retraces={retraces}"))
    out.append(row(
        f"serve/poisson{bits}/naive_cold", cold.makespan_s / n,
        f"ops_s={cold.ops_per_s:.1f} p50_ms={cold.p50_ms:.1f} "
        f"p99_ms={cold.p99_ms:.1f} compiles={naive.stats.traces}"))
    out.append(row(
        f"serve/poisson{bits}/naive_warm", warmed.makespan_s / n,
        f"ops_s={warmed.ops_per_s:.1f} p50_ms={warmed.p50_ms:.1f} "
        f"p99_ms={warmed.p99_ms:.1f} "
        f"engine_speedup={warmed.makespan_s / eng.makespan_s:.2f}x"))

    record(records, op="serve", bits=bits, batch=n, backend="engine",
           seconds_per_call=eng.makespan_s,
           baseline_seconds=cold.makespan_s)
    record(records, op="serve", bits=bits, batch=n,
           backend="engine_vs_warm", seconds_per_call=eng.makespan_s,
           baseline_seconds=warmed.makespan_s)
    record(records, op="serve", bits=bits, batch=n, backend="naive",
           seconds_per_call=cold.makespan_s, baseline_seconds=None)


def _degraded_point(out, records, *, bits, groups, n, rate, seed):
    """Worst-case resilience point: every modexp KERNEL backend's
    breaker is forced open (as if the Pallas tiers were quarantined by
    real failures), so the guarded dispatch must serve the whole trace
    from the jnp fallback tiers.  Ungated record -- the contract is
    that the engine still completes with jnp-tier throughput, no hang
    and no error, not a particular ratio."""
    from repro.resilience.breaker import BREAKER

    templates, warm = build_ops("mod_exp", bits, groups, seed)
    engine = BignumEngine(ServeConfig(), backend=None)
    BREAKER.force_open(op="modexp", backend="pallas")
    BREAKER.force_open(op="modexp", backend="barrett_fused")
    try:
        for w in warm:
            engine.warm(**w)
        retraces0 = _retrace.count("serve")
        res = replay_trace(engine, poisson_trace(templates, n, rate,
                                                 seed=seed))
        retraces = _retrace.count("serve") - retraces0
        if retraces:
            raise AssertionError(
                f"degraded engine retraced {retraces}x post-warm")
    finally:
        BREAKER.clear_forced()
        engine.close()
    out.append(row(
        f"serve/poisson{bits}/degraded", res.makespan_s / n,
        f"ops_s={res.ops_per_s:.1f} p50_ms={res.p50_ms:.1f} "
        f"p99_ms={res.p99_ms:.1f} (kernel breakers forced open; "
        f"jnp-tier dispatch)"))
    record(records, op="serve", bits=bits, batch=n,
           backend="engine_degraded", seconds_per_call=res.makespan_s,
           baseline_seconds=None)


def run(full: bool = False, smoke: bool = False,
        records: list | None = None):
    out = []
    if smoke:
        # rate overloads both servers (warm capacity ~2.5k ops/s at 256
        # bits) so throughput measures capacity, not the arrival clock
        points = [dict(bits=256, groups=3, n=24, rate=10000.0, slots=8)]
    elif full:
        points = [dict(bits=512, groups=4, n=48, rate=1000.0, slots=8),
                  dict(bits=1024, groups=4, n=64, rate=1000.0, slots=8)]
    else:
        points = [dict(bits=1024, groups=4, n=64, rate=1000.0, slots=8)]
    for p in points:
        _replay_point(out, records, seed=p["bits"], **p)
    _degraded_point(out, records, bits=256, groups=2,
                    n=24 if (smoke or not full) else 48,
                    rate=10000.0, seed=256)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--metrics-out", default=None,
                    help="enable observability and write the "
                         "api.metrics() snapshot as JSON")
    ap.add_argument("--trace-out", default=None,
                    help="enable observability and write the span "
                         "buffer as Chrome-trace JSON")
    args = ap.parse_args()
    scope = contextlib.nullcontext()
    if args.metrics_out or args.trace_out:
        from repro import api
        scope = api.configure(observability=True)
    with scope:
        for r in run(full=args.full, smoke=args.smoke):
            print(r)
        if args.metrics_out:
            from repro import api
            with open(args.metrics_out, "w") as f:
                json.dump(api.metrics(), f, indent=1, default=str)
            print(f"# wrote metrics snapshot -> {args.metrics_out}")
        if args.trace_out:
            print(f"# wrote spans -> "
                  f"{obs.write_chrome_trace(args.trace_out)}")
