"""Division subsystem benchmark: reciprocal-divide vs the fused Knuth-D
kernel vs the scalar small-divisor scan.

The structural comparison the dispatcher encodes: at kernel-sized
operands the schoolbook kernel's O(na*nb) VMEM-resident digit steps
amortize better than the Newton chain's multiply launches; above the
threshold the reciprocal path wins because its multiplies ride the
pipeline's subquadratic backends.

Emits machine-readable records (op "div"; the "recip" backend is the
jnp-composition baseline the speedup ratios are measured against) when
driven through benchmarks/run.py --json-out; the committed
benchmarks/BENCH_div.json floors feed `run.py --check-baseline` in CI.
"""
from __future__ import annotations

import jax
import numpy as np

import repro.core.div as DV
from repro.core import limbs as L
from benchmarks.util import record, row, time_fn

BATCH = 256


def _operands(rng, nbits, batch):
    m = nbits // 32
    xs = L.random_bigints(rng, batch, nbits)
    ys = [max(1, y) for y in L.random_bigints(rng, batch, nbits - nbits // 4)]
    import jax.numpy as jnp
    return (jnp.asarray(L.ints_to_batch(xs, m)),
            jnp.asarray(L.ints_to_batch(ys, m)))


def run(full: bool = False, smoke: bool = False, records=None):
    rng = np.random.default_rng(2)
    out = []
    # smoke keeps one kernel-sized width so the --check-baseline keys
    # exist, with few reps: the schoolbook kernel's interpret-mode
    # compile dominates the first call and is excluded by warmup.
    if smoke:
        sizes, batch, iters = (256,), 64, 4
    elif full:
        sizes, batch, iters = (256, 512, 1024, 2048), BATCH, 8
    else:
        sizes, batch, iters = (256, 512), BATCH, 8

    for nbits in sizes:
        a, b = _operands(rng, nbits, batch)
        methods = ["recip"]
        if nbits <= 512:                  # kernel trace cost explodes past
            methods.append("schoolbook")  # this on interpret-mode runners
        t_jnp = None
        for method in methods:
            fn = jax.jit(
                lambda x, y, mm=method: DV.divmod_limbs32(x, y, method=mm))
            t = time_fn(fn, a, b, iters=iters)
            if method == "recip":
                t_jnp = t
            tag = "" if method == "recip" else \
                f"speedup_vs_recip={t_jnp / t:.2f}x"
            out.append(row(f"div/{nbits}b/{method}", t / batch, tag))
            record(records, op="div", bits=nbits, batch=batch,
                   backend=method, seconds_per_call=t,
                   baseline_seconds=t_jnp)

    # the pi workload's scalar fast path (divisor < 2**16)
    import jax.numpy as jnp
    m = 64
    x = jnp.asarray(L.ints_to_batch(L.random_bigints(rng, batch, 32 * m), m))
    from repro.core.mul import split_digits
    xd = split_digits(x, 16)
    fn = jax.jit(lambda v: DV.div_small(v, 12345))
    t = time_fn(fn, xd, iters=iters)
    out.append(row(f"div/small{32 * m}b/scan", t / batch, ""))
    record(records, op="div", bits=32 * m, batch=batch, backend="div_small",
           seconds_per_call=t, baseline_seconds=None)
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
