"""Division subsystem benchmark: reciprocal-divide vs the fused Knuth-D
kernel vs the scalar small-divisor scan.

The structural comparison the dispatcher encodes: at kernel-sized
operands the schoolbook kernel's O(na*nb) VMEM-resident digit steps
amortize better than the Newton chain's multiply launches; above the
threshold the reciprocal path wins because its multiplies ride the
pipeline's subquadratic backends.

Emits machine-readable records (op "div"; the "recip" backend is the
jnp-composition baseline the speedup ratios are measured against) when
driven through benchmarks/run.py --json-out; the committed
benchmarks/BENCH_div.json floors feed `run.py --check-baseline` in CI.
The "recip_cached" row measures the fixed-divisor reciprocal path
(``b_const``) against the same divide with the divisor treated as
runtime data -- the prepared-operand NTT cache's end-to-end win.
"""
from __future__ import annotations

import jax
import numpy as np

import repro.core.div as DV
from repro.core import limbs as L
from benchmarks.util import record, row, time_fn

BATCH = 256


def _operands(rng, nbits, batch):
    m = nbits // 32
    xs = L.random_bigints(rng, batch, nbits)
    ys = [max(1, y) for y in L.random_bigints(rng, batch, nbits - nbits // 4)]
    import jax.numpy as jnp
    return (jnp.asarray(L.ints_to_batch(xs, m)),
            jnp.asarray(L.ints_to_batch(ys, m)))


def run(full: bool = False, smoke: bool = False, records=None):
    rng = np.random.default_rng(2)
    out = []
    # smoke keeps one kernel-sized width so the --check-baseline keys
    # exist, with few reps: the schoolbook kernel's interpret-mode
    # compile dominates the first call and is excluded by warmup.
    if smoke:
        sizes, batch, iters = (256,), 64, 4
    elif full:
        sizes, batch, iters = (256, 512, 1024, 2048), BATCH, 8
    else:
        sizes, batch, iters = (256, 512), BATCH, 8

    for nbits in sizes:
        a, b = _operands(rng, nbits, batch)
        methods = ["recip"]
        if nbits <= 512:                  # kernel trace cost explodes past
            methods.append("schoolbook")  # this on interpret-mode runners
        t_jnp = None
        for method in methods:
            fn = jax.jit(
                lambda x, y, mm=method: DV.divmod_limbs32(x, y, method=mm))
            t = time_fn(fn, a, b, iters=iters)
            if method == "recip":
                t_jnp = t
            tag = "" if method == "recip" else \
                f"speedup_vs_recip={t_jnp / t:.2f}x"
            out.append(row(f"div/{nbits}b/{method}", t / batch, tag))
            record(records, op="div", bits=nbits, batch=batch,
                   backend=method, seconds_per_call=t,
                   baseline_seconds=t_jnp)

    # fixed-divisor reciprocal divide: b_const rides the prepared-operand
    # NTT cache (forward transforms of the divisor's Newton slices and
    # the q*b check multiply are baked once at trace time instead of
    # recomputed per call per lane).  The dividend is twice the divisor
    # width so the Newton chain runs at quotient precision -- the
    # RSA-CRT / base-conversion repeat-divide shape.
    bits_a, bits_b = (4096, 2048) if smoke else (8192, 4096)
    rc_batch = 32 if smoke else 64
    xs = L.random_bigints(rng, rc_batch, bits_a)
    c_int = int(L.random_bigints(rng, 1, bits_b)[0]) | (1 << (bits_b - 1))
    import jax.numpy as jnp
    import repro.api as api
    a_rc = jnp.asarray(L.ints_to_batch([int(x) for x in xs], bits_a // 32))
    b_rc = jnp.asarray(L.ints_to_batch([c_int] * rc_batch, bits_b // 32))
    f_cold = jax.jit(lambda x, y: DV.divmod_limbs32(x, y, method="recip"))
    f_cached = jax.jit(lambda x, y: DV.divmod_limbs32(
        x, y, method="recip", b_const=c_int))
    # the prepared-operand cache lives in the NTT tier; pin the chain's
    # multiplies there (also what keeps this trace O(log n) -- the
    # karatsuba composition takes MINUTES of XLA compile at this width)
    with api.configure(mul_method="ntt"):
        t_cold = time_fn(f_cold, a_rc, b_rc, iters=iters)
        t_cached = time_fn(f_cached, a_rc, b_rc, iters=iters)
    out.append(row(f"div/{bits_a}b_by_{bits_b}b/recip_cached",
                   t_cached / rc_batch,
                   f"speedup_vs_cold={t_cold / t_cached:.2f}x"))
    record(records, op="div", bits=bits_a, batch=rc_batch,
           backend="recip_cached", seconds_per_call=t_cached,
           baseline_seconds=t_cold)

    # the pi workload's scalar fast path (divisor < 2**16)
    m = 64
    x = jnp.asarray(L.ints_to_batch(L.random_bigints(rng, batch, 32 * m), m))
    from repro.core.mul import split_digits
    xd = split_digits(x, 16)
    fn = jax.jit(lambda v: DV.div_small(v, 12345))
    t = time_fn(fn, xd, iters=iters)
    out.append(row(f"div/small{32 * m}b/scan", t / batch, ""))
    record(records, op="div", bits=32 * m, batch=batch, backend="div_small",
           seconds_per_call=t, baseline_seconds=None)
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
