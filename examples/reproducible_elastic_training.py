"""Flagship feature demo: bitwise-reproducible training under elastic
re-grouping, powered by the paper's deferred-carry arithmetic.

Plain f32 gradient accumulation produces DIFFERENT bits when the same
global batch is split into a different number of microbatches (or spread
over a different number of replicas).  The DoT exact reduction --
quantize each fixed-size unit to integer digit planes, add carry-free,
resolve once -- is invariant to any regrouping, which is what makes
"checkpoint on 512 chips, resume on 448" bit-exact.

  PYTHONPATH=src python examples/reproducible_elastic_training.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import exact_accum as EA
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model


def grads_for_units(model, params, units):
    grad_fn = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    return [grad_fn(params, u) for u in units]


def reduce_f32(grads, groups):
    """Simulate `groups` replicas doing f32 partial sums, then combining."""
    per = [None] * groups
    for i, g in enumerate(grads):
        j = i % groups
        per[j] = g if per[j] is None else jax.tree.map(
            lambda a, b: a + b, per[j], g)
    tot = per[0]
    for p in per[1:]:
        tot = jax.tree.map(lambda a, b: a + b, tot, p)
    return tot


def reduce_exact(grads, groups):
    per = [None] * groups
    for i, g in enumerate(grads):
        j = i % groups
        e = jax.tree.map(EA.encode, g)
        per[j] = e if per[j] is None else jax.tree.map(
            lambda a, b: a + b, per[j], e)
    tot = per[0]
    for p in per[1:]:
        tot = jax.tree.map(lambda a, b: a + b, tot, p)
    return jax.tree.map(lambda d: EA.decode(EA.normalize(d)), tot)


def main():
    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8))
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    units = [jax.tree.map(lambda x: x[i:i + 1], batch) for i in range(8)]
    grads = grads_for_units(model, params, units)

    print("reduction of one global batch (8 fixed units) across replica counts:")
    print(f"{'replicas':>9s} {'f32 identical?':>16s} {'exact identical?':>18s}")
    f32_ref = jax.tree.leaves(reduce_f32(grads, 1))
    ex_ref = jax.tree.leaves(reduce_exact(grads, 1))
    for groups in (2, 4, 8):
        f32 = jax.tree.leaves(reduce_f32(grads, groups))
        ex = jax.tree.leaves(reduce_exact(grads, groups))
        f32_same = all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                       for a, b in zip(f32_ref, f32))
        ex_same = all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                      for a, b in zip(ex_ref, ex))
        print(f"{groups:9d} {str(f32_same):>16s} {str(ex_same):>18s}")
    print("\n(the exact column MUST be all True; f32 typically is not)")


if __name__ == "__main__":
    main()
