"""Quickstart: DigitsOnTurbo arithmetic + a tiny LM training run.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import add as A
from repro.core import limbs as L
from repro.core import mul as M


def bignum_demo():
    print("=== DoT big-number arithmetic (paper Algorithms 1 & 2) ===")
    rng = np.random.default_rng(0)
    nbits = 2048
    m = nbits // 32
    batch = 1024

    xs = L.random_bigints(rng, batch, nbits)
    ys = L.random_bigints(rng, batch, nbits)
    a = jnp.asarray(L.ints_to_batch(xs, m))
    b = jnp.asarray(L.ints_to_batch(ys, m))

    s, c = jax.jit(A.dot_add)(a, b)
    ok = all(L.limbs_to_int(np.asarray(s)[i]) +
             (int(np.asarray(c)[i]) << nbits) == xs[i] + ys[i]
             for i in range(8))
    print(f"dot_add: {batch} x {nbits}-bit adds, correct={ok}")

    p = jax.jit(lambda x, y: M.mul_limbs32(x, y, method='auto'))(a, b)
    ok = all(L.limbs_to_int(np.asarray(p)[i]) == xs[i] * ys[i]
             for i in range(4))
    print(f"dot_mul (Karatsuba over DoT base case): correct={ok}")

    # strategy comparison (CPU wall-clock; see benchmarks/ for the full grid)
    for name in ("seq", "two_level_ksa", "carry_select", "dot"):
        fn = jax.jit(lambda x, y, n=name: A.ADD_STRATEGIES[n](x, y))
        fn(a, b)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            fn(a, b)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / 20
        print(f"  add[{name:>14s}]: {dt * 1e6:8.1f} us / {batch} adds")


def tiny_lm_demo():
    print("\n=== 30-step LM training (reduced smollm, synthetic data) ===")
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import build_model
    from repro.train import optimizer as OPT
    from repro.train import trainer as T

    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8))
    tcfg = T.TrainerConfig(opt=OPT.OptConfig(lr=5e-3, warmup_steps=3,
                                             total_steps=30))
    _, _, hist = T.train_loop(model, tcfg, data, steps=30)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    bignum_demo()
    tiny_lm_demo()
