"""End-to-end training driver: smollm-135M (the full assigned config) on
synthetic data with checkpoint/restart and exact deferred-carry gradient
accumulation.

Full run (a few hundred steps of the REAL 135M model):
  PYTHONPATH=src python examples/train_smollm.py --steps 300

CPU-quick variant (reduced config, finishes in ~1 min):
  PYTHONPATH=src python examples/train_smollm.py --quick
"""
import argparse

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/smollm_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "smollm_135m",
            "--steps", str(args.steps if not args.quick else 60),
            "--ckpt-dir", args.ckpt_dir,
            "--grad-reduce", "exact",
            "--microbatches", "2",
            "--lr", "3e-3"]
    if args.quick:
        argv += ["--reduced", "--batch", "8", "--seq", "64"]
    else:
        argv += ["--batch", "4", "--seq", "256", "--ckpt-every", "100"]
    train_launch.main(argv)


if __name__ == "__main__":
    main()
