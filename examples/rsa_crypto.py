"""Batched RSA sign/verify on the DoT Montgomery stack (the OpenSSL-speed
analogue, paper Fig. 5): thousands of independent modexps vectorized over
TPU lanes.

  PYTHONPATH=src python examples/rsa_crypto.py --bits 512 --batch 32 \
      --backend pallas

``--show-dispatch`` traces the run through the observability layer and
prints which modexp backend / window size the dispatchers actually
picked (and which threshold fired).
"""
import argparse
import contextlib
import time

import jax
import numpy as np

from repro.core import limbs as L
from repro.core import rsa as R


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=512)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas", "barrett"),
                    help="modexp backend (core.modular); 'auto' routes "
                         "through the batch-aware MODEXP_DISPATCH (fused "
                         "windowed Pallas ladder for kernel-sized batches)")
    ap.add_argument("--show-dispatch", action="store_true",
                    help="trace dispatch decisions and print the report")
    args = ap.parse_args()
    backend = None if args.backend == "auto" else args.backend

    scope = contextlib.nullcontext()
    if args.show_dispatch:
        from repro import api
        scope = api.configure(observability=True)
    with scope:
        run(args, backend)
    if args.show_dispatch:
        from repro import obs
        print("dispatch report (per-decision, from the trace buffer):")
        for line in obs.format_report():
            print(line)


def run(args, backend):

    key = R.generate_key(bits=args.bits, seed=1)
    msgs = [R.digest_int(f"message-{i}".encode(), args.bits)
            for i in range(args.batch)]
    md = R.messages_to_digits(msgs, key)

    sign = jax.jit(lambda m: R.sign(m, key, backend=backend))
    verify = jax.jit(lambda s: R.verify(s, key, backend=backend))

    sigs = sign(md)
    sigs.block_until_ready()
    t0 = time.time()
    sigs = sign(md)
    sigs.block_until_ready()
    t_sign = time.time() - t0

    back = verify(sigs)
    back.block_until_ready()
    t0 = time.time()
    back = verify(sigs)
    back.block_until_ready()
    t_verify = time.time() - t0

    ok = all(L.limbs_to_int(np.asarray(back)[i], 16) == msgs[i] % key.n
             for i in range(args.batch))
    print(f"RSA-{args.bits} [{args.backend}]: batch={args.batch} "
          f"roundtrip correct={ok}")
    print(f"  sign:   {t_sign * 1e3:8.1f} ms  ({args.batch / t_sign:7.1f} ops/s)")
    print(f"  verify: {t_verify * 1e3:8.1f} ms  ({args.batch / t_verify:7.1f} ops/s)")
    # oracle check on one signature
    assert L.limbs_to_int(np.asarray(sigs)[0], 16) == pow(
        msgs[0] % key.n, key.d, key.n)


if __name__ == "__main__":
    main()
