"""Compute pi with DoT fixed-point bignums (GMPbench's pi workload,
paper Fig. 4: the biggest end-to-end win because Machin's series is pure
add/sub/div-small).

  PYTHONPATH=src python examples/pi_digits.py --digits 200
"""
import argparse
import time

from repro.core import pi as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--digits", type=int, default=200)
    args = ap.parse_args()

    t0 = time.time()
    got = P.pi_digits(args.digits)
    dt = time.time() - t0
    want = P.pi_reference(args.digits)
    match = sum(1 for a, b in zip(got, want) if a == b)
    print(f"pi ({args.digits} digits, {dt:.2f}s):")
    print(got)
    print(f"matches Python-int oracle on {match}/{len(want)} chars "
          f"(trailing digits differ only by guard rounding)")
    assert got[: args.digits - 4] == want[: args.digits - 4]


if __name__ == "__main__":
    main()
