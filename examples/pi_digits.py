"""Compute pi with DoT fixed-point bignums (GMPbench's pi workload,
paper Fig. 4) -- now END-TO-END on device: Machin's series runs on
div_small + DoT add/sub, and the decimal rendering runs on the division
subsystem's divide-and-conquer base conversion (core/div.to_decimal),
so the host only ever sees the final digit array.

  PYTHONPATH=src python examples/pi_digits.py --digits 1000
"""
import argparse
import time

from repro.core import pi as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--digits", type=int, default=1000)
    args = ap.parse_args()

    t0 = time.time()
    got = P.pi_digits(args.digits)
    dt = time.time() - t0
    want = P.pi_reference(args.digits)
    match = sum(1 for a, b in zip(got, want) if a == b)
    print(f"pi ({args.digits} digits, {dt:.2f}s, series + base conversion "
          f"on device):")
    print(got)
    print(f"matches Python-int oracle on {match}/{len(want)} chars "
          f"(trailing digits differ only by guard rounding)")
    assert got[: args.digits - 4] == want[: args.digits - 4]
    verified = match - 2                    # "3." prefix
    print(f"verified {verified} decimal digits against the oracle")


if __name__ == "__main__":
    main()
