"""Compute pi with DoT fixed-point bignums (GMPbench's pi workload,
paper Fig. 4) -- END-TO-END on device: Machin's series runs on
div_small + DoT add/sub, and the decimal rendering runs on the division
subsystem's divide-and-conquer base conversion (core/div.to_decimal),
so the host only ever sees the final digit array.

  PYTHONPATH=src python examples/pi_digits.py --digits 1000

``--digits`` scales past the old ~1200-digit practical ceiling: beyond
that, the scale-by-10**n multiply and every base-conversion divmod run
wider than 4096 bits, where the batch-1 dispatch used to fall back to
the jnp Karatsuba composition -- whose XLA compile takes minutes PER
MULTIPLY WIDTH at those sizes (and the base conversion uses many).
Those multiplies now ride the fused NTT/CRT kernels (kernels/ntt_mul,
O(log n) trace), so the per-width compile cliff is gone; what remains
at large ``--digits`` is the one-time XLA compile of the whole fused
series+conversion program plus the series arithmetic itself (1400
digits: ~8 min total on CPU interpret, all 1400 digits verified).
``--show-dispatch`` turns on the observability layer for the run and
prints the REAL dispatch decisions afterwards (which multiply/divide
tier every width actually took, and why); ``--trace-out`` additionally
writes the span buffer as Chrome-trace JSON.
"""
import argparse
import contextlib
import time

from repro.core import pi as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--digits", type=int, default=1000)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the Python-int oracle comparison")
    ap.add_argument("--show-dispatch", action="store_true",
                    help="trace dispatch decisions and print the report")
    ap.add_argument("--trace-out", default=None,
                    help="write spans as Chrome-trace JSON (implies "
                         "--show-dispatch)")
    args = ap.parse_args()

    scope = contextlib.nullcontext()
    if args.show_dispatch or args.trace_out:
        from repro import api, obs
        scope = api.configure(observability=True)

    t0 = time.time()
    with scope:
        got = P.pi_digits(args.digits)
        if args.show_dispatch or args.trace_out:
            print("dispatch report (per-decision, from the trace buffer):")
            for line in obs.format_report():
                print(line)
            if args.trace_out:
                print(f"wrote spans -> "
                      f"{obs.write_chrome_trace(args.trace_out)}")
    dt = time.time() - t0
    print(f"pi ({args.digits} digits, {dt:.2f}s, series + base conversion "
          f"on device):")
    print(got)
    if args.no_verify:
        return
    want = P.pi_reference(args.digits)
    match = sum(1 for a, b in zip(got, want) if a == b)
    print(f"matches Python-int oracle on {match}/{len(want)} chars "
          f"(trailing digits differ only by guard rounding)")
    assert got[: args.digits - 4] == want[: args.digits - 4]
    verified = match - 2                    # "3." prefix
    print(f"verified {verified} decimal digits against the oracle")


if __name__ == "__main__":
    main()
