"""Serve a small model with batched requests through the slot engine.

  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve as serve_launch

if __name__ == "__main__":
    serve_launch.main(["--arch", "gemma2_2b", "--reduced",
                       "--requests", "6", "--prompt-len", "8",
                       "--max-new", "12", "--slots", "3"])
