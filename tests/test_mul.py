"""DoT multiplication (all paths) vs the Python-int oracle."""
import numpy as np
import pytest

from repro.core import limbs as L
import repro.core.mul as M

RNG = np.random.default_rng(1)


def _digits(xs, nd, bits=16):
    return np.stack([L.int_to_limbs(x, nd, bits) for x in xs])


def _check_product_digits(p, xs, ys, bits):
    p = np.asarray(p)
    for i, (x, y) in enumerate(zip(xs, ys)):
        got = L.limbs_to_int(p[i], bits)
        assert got == x * y, f"idx {i}: {x}*{y} got {got}"


@pytest.mark.parametrize("nbits", [64, 128, 256, 512])
def test_dot_mul_random(nbits):
    nd = nbits // 16
    xs = L.random_bigints(RNG, 8, nbits)
    ys = L.random_bigints(RNG, 8, nbits)
    p = M.dot_mul(_digits(xs, nd), _digits(ys, nd))
    assert p.shape[-1] == 2 * nd
    _check_product_digits(p, xs, ys, 16)


def test_dot_mul_pathological():
    nbits = 256
    nd = nbits // 16
    pairs = L.pathological_pairs(nbits, bits=16)
    xs = [p[0] for p in pairs]
    ys = [p[1] for p in pairs]
    p = M.dot_mul(_digits(xs, nd), _digits(ys, nd))
    _check_product_digits(p, xs, ys, 16)


def test_dot_mul_scan_normalize_matches():
    nbits = 256
    nd = nbits // 16
    xs = L.random_bigints(RNG, 4, nbits)
    ys = L.random_bigints(RNG, 4, nbits)
    p1 = M.dot_mul(_digits(xs, nd), _digits(ys, nd), normalize="dot")
    p2 = M.dot_mul(_digits(xs, nd), _digits(ys, nd), normalize="scan")
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("nbits", [128, 256, 448])
def test_dot_mul_mxu(nbits):
    nd = -(-nbits // 7)
    xs = L.random_bigints(RNG, 8, nbits)
    ys = L.random_bigints(RNG, 8, nbits)
    a = np.stack([L.int_to_limbs(x, nd, 7, np.int8) for x in xs])
    b = np.stack([L.int_to_limbs(y, nd, 7, np.int8) for y in ys])
    p = M.dot_mul_mxu(a, b)
    _check_product_digits(p, xs, ys, 7)


@pytest.mark.parametrize("nbits", [128, 256])
def test_mul_schoolbook(nbits):
    nd = nbits // 16
    xs = L.random_bigints(RNG, 8, nbits)
    ys = L.random_bigints(RNG, 8, nbits)
    p = M.mul_schoolbook(_digits(xs, nd), _digits(ys, nd))
    _check_product_digits(p, xs, ys, 16)


@pytest.mark.parametrize("nbits", [512, 1024, 1536])
def test_mul_karatsuba(nbits):
    nd = nbits // 16
    xs = L.random_bigints(RNG, 4, nbits)
    ys = L.random_bigints(RNG, 4, nbits)
    p = M.mul_karatsuba(_digits(xs, nd), _digits(ys, nd), threshold=8)
    _check_product_digits(p[..., : 2 * nd], xs, ys, 16)


def test_mul_karatsuba_pathological():
    nbits = 512
    nd = nbits // 16
    pairs = L.pathological_pairs(nbits, bits=16)
    xs = [p[0] for p in pairs]
    ys = [p[1] for p in pairs]
    p = M.mul_karatsuba(_digits(xs, nd), _digits(ys, nd), threshold=8)
    _check_product_digits(p[..., : 2 * nd], xs, ys, 16)


@pytest.mark.parametrize("method", ["dot", "mxu", "schoolbook", "karatsuba", "auto"])
@pytest.mark.parametrize("nbits", [256, 1024])
def test_mul_limbs32_roundtrip(method, nbits):
    m = nbits // 32
    xs = L.random_bigints(RNG, 4, nbits)
    ys = L.random_bigints(RNG, 4, nbits)
    a = L.ints_to_batch(xs, m)
    b = L.ints_to_batch(ys, m)
    p = M.mul_limbs32(a, b, method=method)
    p = np.asarray(p)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(p[i], 32) == x * y


def test_split_join_digits_roundtrip():
    m = 8
    xs = L.random_bigints(RNG, 8, 32 * m)
    a = L.ints_to_batch(xs, m)
    for bits in (7, 13, 16, 26):
        d = M.split_digits(a, bits)
        for i, x in enumerate(xs):
            assert L.limbs_to_int(np.asarray(d)[i], bits) == x
        back = M.join_digits(d, bits, m)
        np.testing.assert_array_equal(np.asarray(back), a)
