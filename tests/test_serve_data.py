"""Serving engine consistency (prefill+decode == teacher forcing) and the
deterministic data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.serve.engine import EngineConfig, Request, ServeEngine


def test_data_pipeline_deterministic_and_shardable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    d = SyntheticLM(cfg)
    b1 = d.batch(5)
    b2 = d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # row-slice sharding matches the full batch
    rows = d.batch(5, rows=slice(0, 4))
    np.testing.assert_array_equal(rows["tokens"], b1["tokens"][:4])
    # next-token structure: targets are the affine successor of tokens
    assert np.all(b1["targets"] == (b1["tokens"] * cfg.mult + cfg.inc) % cfg.vocab_size)


def test_decode_matches_teacher_forcing():
    """prefill + step-by-step decode reproduces full-forward logits."""
    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(B, S + 8))
    logits_p, cache = jax.jit(model.prefill)(
        params, {"tokens": toks[:, :S - 4]}, cache)
    # decode the remaining 4 tokens with teacher forcing
    decode = jax.jit(model.decode_step)
    got = [logits_p]
    for t in range(S - 4, S):
        lg, cache = decode(params, cache, toks[:, t:t + 1], jnp.int32(t))
        got.append(lg)

    # reference: prefill over longer prefixes
    for i, t_end in enumerate(range(S - 4, S + 1)):
        cache2 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              model.cache_specs(B, S + 8))
        ref, _ = jax.jit(model.prefill)(
            params, {"tokens": toks[:, :t_end]}, cache2)
        np.testing.assert_allclose(
            np.asarray(got[i], np.float32), np.asarray(ref, np.float32),
            atol=2e-2, rtol=2e-2)


def test_serve_engine_greedy():
    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    eng = ServeEngine(model, params, EngineConfig(slots=2, max_seq=64))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
                    max_new_tokens=5) for i in range(3)]
    out = eng.run(reqs)
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 5 for v in out.values())
    # determinism
    out2 = ServeEngine(model, params, EngineConfig(slots=2, max_seq=64)).run(reqs)
    assert out == out2


def test_serve_engine_eos_early_stop():
    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    free = ServeEngine(model, params, EngineConfig(slots=2, max_seq=64))
    ref = free.run([Request(rid=0, prompt=prompt, max_new_tokens=8)])[0]
    assert len(ref) == 8
    # re-run with eos set to a token the model actually emits mid-stream:
    # generation must stop AT the eos token, not run to max_new_tokens
    eos = ref[3]
    stop = ServeEngine(model, params,
                       EngineConfig(slots=2, max_seq=64, eos_id=eos))
    got = stop.run([Request(rid=0, prompt=prompt, max_new_tokens=8)])[0]
    k = ref.index(eos)
    assert got == ref[: k + 1]            # truncated at first eos, inclusive
    assert len(got) < 8


def test_serve_engine_multi_wave_refill():
    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.key(4))
    rng = np.random.default_rng(6)
    # equal prompt lengths => identical left-padding in every wave, so
    # slot grouping must not change any request's output
    reqs = [Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, (5,)).astype(np.int32),
                max_new_tokens=3 + (i % 3)) for i in range(5)]
    waves = ServeEngine(model, params,
                        EngineConfig(slots=2, max_seq=64)).run(reqs)
    single = ServeEngine(model, params,
                         EngineConfig(slots=8, max_seq=64)).run(reqs)
    assert set(waves) == {0, 1, 2, 3, 4}
    for r in reqs:                        # per-request budget respected
        assert len(waves[r.rid]) == r.max_new_tokens
    assert waves == single                # refill waves == one big batch
