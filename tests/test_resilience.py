"""resilience/: breaker state machine (fake clock), deterministic
fault injection, guarded tiered execution, and residue self-checking.
Everything here is host-side -- no kernels compile -- so the state
machines are tested exactly, not statistically."""
import warnings

import numpy as np
import pytest

from repro import api, config
from repro.obs import metrics as _metrics
from repro.resilience import guard, inject, selfcheck
from repro.resilience.breaker import BREAKER, CircuitBreaker, shape_bucket


@pytest.fixture(autouse=True)
def _clean():
    inject.clear()
    BREAKER.reset()
    yield
    inject.clear()
    BREAKER.reset()
    config.set_overrides({"selfcheck": None})
    config.set_overrides({"kernel_fallback": None})


# ---------------------------------------------------------------------------
# breaker
# ---------------------------------------------------------------------------

def test_shape_bucket_powers_of_two():
    assert shape_bucket(1) == 32
    assert shape_bucket(32) == 32
    assert shape_bucket(33) == 64
    assert shape_bucket(1024) == 1024
    assert shape_bucket(1040) == 2048


def test_breaker_state_machine_fake_clock():
    t = [0.0]
    br = CircuitBreaker(cooldown_s=10.0, clock=lambda: t[0])
    assert br.state("mul", 256, "pallas") == "closed"
    assert br.allow("mul", 256, "pallas")
    br.record_failure("mul", 256, "pallas")
    assert br.state("mul", 256, "pallas") == "open"
    assert not br.allow("mul", 256, "pallas")
    # other shapes/backends unaffected
    assert br.allow("mul", 4096, "pallas")
    assert br.allow("mul", 256, "jnp")
    # cooldown expires -> half_open, exactly ONE probe allowed
    t[0] = 10.0
    assert br.state("mul", 256, "pallas") == "half_open"
    assert br.allow("mul", 256, "pallas")        # the probe
    assert not br.allow("mul", 256, "pallas")    # everyone else blocked
    br.record_failure("mul", 256, "pallas")      # probe failed: re-open
    assert br.state("mul", 256, "pallas") == "open"
    assert not br.allow("mul", 256, "pallas")
    t[0] = 20.0
    assert br.allow("mul", 256, "pallas")
    br.record_success("mul", 256, "pallas")      # probe passed: close
    assert br.state("mul", 256, "pallas") == "closed"
    assert br.allow("mul", 256, "pallas")


def test_breaker_force_open_and_snapshot():
    t = [0.0]
    br = CircuitBreaker(cooldown_s=5.0, clock=lambda: t[0])
    br.force_open(op="modexp", backend="pallas")
    assert not br.allow("modexp", 256, "pallas")
    assert br.state("modexp", 1024, "pallas") == "open"
    assert br.allow("modexp", 256, "jnp")        # pattern is keyed
    assert br.allow("mul", 256, "pallas")
    br.record_failure("mul", 512, "jnp")
    snap = br.snapshot()
    assert snap["forced"] == [{"op": "modexp", "backend": "pallas"}]
    assert snap["keys"]["mul/512/jnp"]["state"] == "open"
    assert snap["keys"]["mul/512/jnp"]["retry_in_s"] == pytest.approx(5.0)
    br.clear_forced()
    assert br.allow("modexp", 256, "pallas")


# ---------------------------------------------------------------------------
# injection
# ---------------------------------------------------------------------------

def test_inject_every_and_count_cadence():
    inject.install("compile_fail", "mul/pallas", every=2, count=2)
    fired = 0
    for _ in range(10):
        try:
            inject.fire("mul/pallas")
        except inject.InjectedFault:
            fired += 1
    assert fired == 2                        # calls 2 and 4, capped at 2
    assert [e["seq"] for e in inject.log()] == [1, 2]
    inject.fire("mul/jnp")                   # site mismatch: no-op


def test_inject_corrupt_deterministic():
    block = np.arange(12, dtype=np.uint32).reshape(4, 3)
    inject.install("corrupt", "serve/flush", seed=7)
    out1 = inject.corrupt("serve/flush/mod_exp", block.copy(), 2)
    inject.clear()
    inject.install("corrupt", "serve/flush", seed=7)
    out2 = inject.corrupt("serve/flush/mod_exp", block.copy(), 2)
    assert np.array_equal(out1, out2)        # same seed => same flip
    diff = np.nonzero(out1 != block)
    assert len(diff[0]) == 1                 # exactly one limb touched
    assert diff[0][0] < 2                    # only REAL lanes corrupted
    e = inject.log()[0]
    assert (e["lane"], e["limb"]) == (diff[0][0], diff[1][0])
    delta = int(out1[diff][0]) ^ int(block[diff][0])
    assert delta == 1 << e["bit"]            # single-bit flip


# ---------------------------------------------------------------------------
# guard
# ---------------------------------------------------------------------------

def _fallback_count(**labels):
    return _metrics.REGISTRY.counter(guard.METRIC).total(**labels)


def test_guard_falls_through_and_quarantines():
    calls = []

    def bad():
        calls.append("pallas")
        raise RuntimeError("Mosaic lowering failed")

    def good():
        calls.append("jnp")
        return 42

    t0 = _fallback_count(op="t_op")
    out = guard.run("t_op", 256, [("pallas", bad), ("jnp", good)])
    assert out == 42 and calls == ["pallas", "jnp"]
    assert _fallback_count(op="t_op", backend="pallas",
                           reason="lowering") - 0 == 1
    # breaker opened: next run skips the failing tier outright
    out = guard.run("t_op", 256, [("pallas", bad), ("jnp", good)])
    assert out == 42 and calls == ["pallas", "jnp", "jnp"]
    assert _fallback_count(op="t_op", reason="quarantined") == 1
    assert _fallback_count(op="t_op") - t0 == 2


def test_guard_final_tier_never_skipped_and_raises():
    def bad():
        raise RuntimeError("boom")

    BREAKER.record_failure("t_final", shape_bucket(256), "jnp")
    # final tier runs even with its breaker key open...
    assert guard.run("t_final", 256, [("jnp", lambda: 7)]) == 7
    # ...and its exception propagates (nothing left to fall back to)
    with pytest.raises(RuntimeError, match="boom"):
        guard.run("t_final", 256, [("pallas", bad), ("jnp", bad)])


def test_guard_strict_mode():
    def bad():
        raise RuntimeError("boom")

    config.set_overrides({"kernel_fallback": False})
    with pytest.raises(RuntimeError, match="boom"):
        guard.run("t_strict", 256, [("pallas", bad), ("jnp", lambda: 1)])
    # quarantine skipping still applies in strict mode
    assert guard.run("t_strict", 256,
                     [("pallas", bad), ("jnp", lambda: 1)]) == 1
    config.set_overrides({"kernel_fallback": None})


def test_guard_injected_fault_classified():
    inject.install("compile_fail", "t_inj/pallas")
    out = guard.run("t_inj", 512, [("pallas", lambda: 0),
                                   ("jnp", lambda: 9)])
    assert out == 9
    assert _fallback_count(op="t_inj", reason="injected") == 1
    assert len(inject.log()) == 1


def test_classify_reasons():
    assert guard.classify(inject.InjectedFault("x")) == "injected"
    assert guard.classify(RuntimeError("RESOURCE_EXHAUSTED: vmem")) == "oom"
    assert guard.classify(NotImplementedError("no lowering")) == "lowering"
    assert guard.classify(RuntimeError("compilation failure")) == "compile"
    assert guard.classify(KeyError("k")) == "KeyError"


# ---------------------------------------------------------------------------
# selfcheck
# ---------------------------------------------------------------------------

def test_fold_matches_int_mod_p():
    rng = np.random.default_rng(3)
    batch = rng.integers(0, 1 << 32, size=(8, 9), dtype=np.uint32)
    folds = selfcheck.fold_limbs(batch)
    for row, f in zip(batch, folds):
        assert int(f) == api.from_limbs(row) % selfcheck.P


def test_check_mul_catches_bit_flip():
    config.set_overrides({"selfcheck": "raise"})
    a = api.to_limbs([3, 5, (1 << 90) - 7], 96)
    b = api.to_limbs([7, 11, (1 << 80) + 9], 96)
    out = np.asarray(api.to_limbs(
        [ints_a * ints_b for ints_a, ints_b in
         zip(api.from_limbs(a), api.from_limbs(b))], 192))
    selfcheck.check_mul(a, b, out)           # exact product passes
    bad = out.copy()
    bad[1, 2] ^= np.uint32(1 << 13)
    with pytest.raises(selfcheck.SelfCheckError, match="1 mul lane"):
        selfcheck.check_mul(a, b, bad)
    assert _metrics.REGISTRY.counter(selfcheck.METRIC).total(op="mul") >= 1
    config.set_overrides({"selfcheck": "warn"})
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        selfcheck.check_mul(a, b, bad)
    assert any(issubclass(x.category, selfcheck.SelfCheckWarning)
               for x in w)


def test_check_divmod_identity():
    config.set_overrides({"selfcheck": "raise"})
    ints_a = [12345678901234567890, 999]
    ints_b = [97, 1000]
    a, b = api.to_limbs(ints_a, 96), api.to_limbs(ints_b, 96)
    q = api.to_limbs([x // y for x, y in zip(ints_a, ints_b)], 96)
    r = api.to_limbs([x % y for x, y in zip(ints_a, ints_b)], 96)
    selfcheck.check_divmod(a, b, q, r)
    bad = np.asarray(q).copy()
    bad[0, 0] ^= np.uint32(1)
    with pytest.raises(selfcheck.SelfCheckError):
        selfcheck.check_divmod(a, b, bad, r)


def test_verify_and_repair_lanes():
    key = api.generate_key(96, seed=21)
    msg = 0xABCDEF % key.n
    sig = pow(msg, key.d, key.n)
    assert selfcheck.verify_lane("rsa_sign", msg, sig, key=key)
    assert not selfcheck.verify_lane("rsa_sign", msg, sig ^ 1, key=key)
    assert selfcheck.repair_lane("rsa_sign", msg, key=key) == sig
    n, e = 1000003, 65537
    assert selfcheck.verify_lane("mod_exp", 5, pow(5, e, n),
                                 modulus=n, exponent=e)
    assert selfcheck.repair_lane("mod_exp", 5, modulus=n,
                                 exponent=e) == pow(5, e, n)
    with pytest.raises(ValueError, match="unknown op"):
        selfcheck.verify_lane("nope", 1, 1)


def test_selfcheck_disabled_is_noop():
    assert not selfcheck.enabled()
    a = api.to_limbs([3], 96)
    bad = np.asarray(api.to_limbs([999], 192))   # wrong on purpose
    selfcheck.check_mul(a, a, bad)               # no policy -> no check


# ---------------------------------------------------------------------------
# configure() knobs
# ---------------------------------------------------------------------------

def test_configure_selfcheck_and_kernel_fallback():
    with api.configure(selfcheck="warn", kernel_fallback=False):
        assert selfcheck.policy() == "warn"
        assert not guard.fallback_enabled()
    assert selfcheck.policy() is None
    assert guard.fallback_enabled()
    with pytest.raises(ValueError, match="selfcheck"):
        api.configure(selfcheck="explode")
    with pytest.raises(ValueError, match="kernel_fallback"):
        api.configure(kernel_fallback="yes")
