"""Trainer: loss decreases; exact deferred-carry accumulation is bitwise
invariant to microbatch regrouping (the paper's technique as a feature)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.train import optimizer as OPT
from repro.train import trainer as T


def _setup(microbatches=1, grad_reduce="mean"):
    cfg = get_config("smollm_135m", reduced=True).replace(remat="none")
    model = build_model(cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=0))
    tcfg = T.TrainerConfig(
        opt=OPT.OptConfig(lr=1e-2, warmup_steps=2, total_steps=40),
        microbatches=microbatches, grad_reduce=grad_reduce)
    return model, data, tcfg


def test_loss_decreases():
    model, data, tcfg = _setup()
    params, opt, hist = T.train_loop(model, tcfg, data, steps=30)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.8, f"loss did not decrease: {first} -> {last}"
    assert np.isfinite(last)


def test_microbatch_matches_full_batch_roughly():
    model, data, tcfg1 = _setup(1)
    _, _, tcfg4 = _setup(4)[1:], None, None
    model1, data1, t1 = _setup(1)
    model4, data4, t4 = _setup(4)
    params = model1.init(jax.random.key(0))
    opt = OPT.init(params)
    b = jax.tree.map(jnp.asarray, data1.batch(0))
    s1 = jax.jit(T.make_train_step(model1, t1))
    s4 = jax.jit(T.make_train_step(model4, t4))
    p1, _, m1 = s1(params, opt, b)
    p4, _, m4 = s4(params, opt, b)
    # same loss value (forward identical), params close (mean-of-grads)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-3)
    l1 = jax.tree.leaves(p1)[0]
    l4 = jax.tree.leaves(p4)[0]
    # Adam turns tiny bf16 grad diffs into lr-scale update diffs; this is
    # a sanity bound, exactness is covered by the exact-accum test below.
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l4, np.float32), atol=2.5e-2)


def test_exact_accum_bitwise_invariant_to_grouping():
    """The elastic-rescaling property: with a FIXED quantization unit (one
    fixed-size microbatch), any assignment of the encoded units to
    replicas/steps -- order, grouping, replica count -- produces bitwise
    identical reduced gradients."""
    from repro.core import exact_accum as EA

    model, data, _ = _setup()
    params = model.init(jax.random.key(1))
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    K = 8                                     # 8 fixed units of 1 example
    mbs = T._split_microbatches(batch, K)
    grad_fn = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    encs = []
    for i in range(K):
        mb = jax.tree.map(lambda x: x[i], mbs)
        g = grad_fn(params, mb)
        encs.append(jax.tree.map(lambda x: np.asarray(EA.encode(x)), g))

    def reduce_order(order, groups):
        """Sum in `groups` chunks (simulating that many replicas)."""
        per_group = [None] * groups
        for j, idx in enumerate(order):
            gslot = j % groups
            cur = per_group[gslot]
            per_group[gslot] = encs[idx] if cur is None else jax.tree.map(
                lambda a, b: a + b, cur, encs[idx])
        total = per_group[0]
        for g in per_group[1:]:
            total = jax.tree.map(lambda a, b: a + b, total, g)
        return jax.tree.map(
            lambda d: np.asarray(EA.decode(EA.normalize(jnp.asarray(d)))),
            total)

    ref = reduce_order(list(range(K)), 1)
    for order, groups in [(list(reversed(range(K))), 1),
                          ([3, 1, 7, 0, 5, 2, 6, 4], 2),
                          (list(range(K)), 4),
                          ([5, 0, 3, 6, 1, 4, 7, 2], 8)]:
        out = reduce_order(order, groups)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            assert a.tobytes() == b.tobytes(), \
                f"not bitwise invariant for order={order} groups={groups}"
