"""Checkpoint/restore, integrity (CRC + RSA), restart fallback, straggler
monitor, elastic planning."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C
from repro.train import fault_tolerance as FT


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.arange(16, dtype=jnp.float32)},
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    st = _state()
    C.save(tmp_path, 10, st)
    back, manifest = C.restore(tmp_path / "step_000000010", st)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected_and_fallback(tmp_path):
    st = _state()
    C.save(tmp_path, 1, st)
    C.save(tmp_path, 2, st)
    # corrupt latest: flip bytes in one array
    target = tmp_path / "step_000000002" / "arr_00000.npy"
    raw = bytearray(target.read_bytes())
    raw[-8] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(C.CheckpointError):
        C.validate(tmp_path / "step_000000002")
    rm = FT.RestartManager(tmp_path)
    assert rm.latest_valid_step() == 1
    step, back = rm.resume(st)
    assert step == 1


def test_signature_tamper_detected(tmp_path):
    st = _state()
    C.save(tmp_path, 3, st)
    mf = tmp_path / "step_000000003" / "manifest.json"
    m = json.loads(mf.read_text())
    m["extra"]["evil"] = True      # mutate signed content
    mf.write_text(json.dumps(m))
    with pytest.raises(C.CheckpointError):
        C.validate(tmp_path / "step_000000003")


def test_keep_last_prunes(tmp_path):
    st = _state()
    for s in range(6):
        C.save(tmp_path, s, st, keep_last=2)
    assert C.list_steps(tmp_path) == [4, 5]


def test_async_saver(tmp_path):
    st = _state()
    sv = C.AsyncSaver(tmp_path, keep_last=2)
    sv.save(1, st)
    sv.save(2, st)
    sv.wait()
    assert C.list_steps(tmp_path) == [1, 2]


def test_straggler_monitor():
    mon = FT.StragglerMonitor(window=20, threshold=2.0, trip_count=2)
    for i in range(10):
        assert mon.record(i, 1.0) is None
    ev = mon.record(10, 3.0)
    assert ev is not None and ev.action == "observe"
    ev = mon.record(11, 3.5)
    assert ev is not None and ev.action == "checkpoint_and_replace_host"
    assert mon.record(12, 1.0) is None   # recovery resets the trip counter


def test_elastic_plan():
    p = FT.plan_elastic(256)
    assert p.new_mesh_shape == (16, 16)
    p = FT.plan_elastic(250)   # lost 6 chips -> round down, keep TP
    assert p.new_mesh_shape == (15, 16)
    p = FT.plan_elastic(512)
    assert p.new_mesh_shape == (2, 16, 16)
    with pytest.raises(ValueError):
        FT.plan_elastic(3)
