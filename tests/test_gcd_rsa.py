"""Direct oracle coverage for core/gcd.py and core/rsa.py (first time
either has its own test module; previously they were exercised only
through examples and benchmarks).

gcd: batched binary GCD lanes vs math.gcd, plus the structural edge
cases every branch of the masked select tree must handle (coprime pairs,
equal operands, zero lanes, powers of two with a shared 2-adic part).

rsa: host keygen + batched sign/verify roundtrip, CRT decrypt against
the plain full-ladder decrypt, and tampered-signature rejection, at
256 and 512 bits.  Batches stay below the fused-kernel threshold so the
jnp windowed ladder runs (the fused kernel has its own oracle suite in
test_modexp_window.py).
"""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import gcd as G
from repro.core import limbs as L
from repro.core import rsa as R

RNG = np.random.default_rng(23)
DIGIT_BITS = 16


def _digits(ints, nbits):
    nd = nbits // DIGIT_BITS
    return jnp.asarray(np.stack(
        [L.int_to_limbs(v, nd, DIGIT_BITS) for v in ints]))


def _check_gcd(us, vs, nbits):
    got = np.asarray(G.gcd(_digits(us, nbits), _digits(vs, nbits)))
    for i, (u, v) in enumerate(zip(us, vs)):
        assert L.limbs_to_int(got[i], DIGIT_BITS) == math.gcd(u, v), (i, u, v)


# ---------------------------------------------------------------------------
# gcd vs math.gcd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", [256, 512])
def test_gcd_random_lanes(nbits):
    us = L.random_bigints(RNG, 12, nbits)
    vs = L.random_bigints(RNG, 12, nbits)
    _check_gcd(us, vs, nbits)


@pytest.mark.parametrize("nbits", [256, 512])
def test_gcd_shared_factor(nbits):
    """Lanes with a large constructed common divisor (the interesting
    case: the result is wide, not a small integer)."""
    g = L.random_bigints(RNG, 6, nbits // 2)
    a = L.random_bigints(RNG, 6, nbits // 2 - 1)
    b = L.random_bigints(RNG, 6, nbits // 2 - 1)
    us = [x * y for x, y in zip(g, a)]
    vs = [x * y for x, y in zip(g, b)]
    _check_gcd(us, vs, nbits)


def test_gcd_edge_cases():
    nbits = 256
    full = (1 << nbits) - 1
    cases = [
        (0, 0),                      # gcd(0, 0) = 0
        (0, 12345),                  # gcd(0, v) = v
        (67890, 0),                  # gcd(u, 0) = u
        (full, full),                # equal operands
        (1, full),                   # coprime by construction
        (3, 5),                      # tiny coprime
        (1 << 200, 1 << 120),        # powers of two: min 2-adic part
        (12 << 100, 18 << 100),      # shared odd and 2-adic factors
    ]
    _check_gcd([c[0] for c in cases], [c[1] for c in cases], nbits)


def test_gcd_batch_of_one_and_leading_dims():
    nbits = 256
    us = L.random_bigints(RNG, 4, nbits)
    vs = L.random_bigints(RNG, 4, nbits)
    one = np.asarray(G.gcd(_digits(us[:1], nbits), _digits(vs[:1], nbits)))
    assert L.limbs_to_int(one[0], DIGIT_BITS) == math.gcd(us[0], vs[0])
    nd = nbits // DIGIT_BITS
    got = np.asarray(G.gcd(_digits(us, nbits).reshape(2, 2, nd),
                           _digits(vs, nbits).reshape(2, 2, nd)))
    flat = got.reshape(4, nd)
    for i in range(4):
        assert L.limbs_to_int(flat[i], DIGIT_BITS) == math.gcd(us[i], vs[i])


# ---------------------------------------------------------------------------
# rsa: sign/verify roundtrip, CRT decrypt, tamper rejection.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module",
                params=[256, pytest.param(512, marks=pytest.mark.slow)])
def key(request):
    """256-bit keys run in the PR-fast subset; the 512-bit grid rides
    the full suite (ladder tracing dominates, ~2 min for the module)."""
    return R.generate_key(bits=request.param, seed=7)


def _messages(key, count=4):
    msgs = [R.digest_int(f"msg-{i}".encode(), key.bits)
            for i in range(count)]
    return msgs, R.messages_to_digits(msgs, key)


def test_sign_verify_roundtrip(key):
    msgs, m_dig = _messages(key)
    sig = R.sign(m_dig, key)
    back = np.asarray(R.verify(sig, key))
    for i, msg in enumerate(msgs):
        assert L.limbs_to_int(back[i], DIGIT_BITS) == msg % key.n, i


def test_sign_matches_python_pow(key):
    msgs, m_dig = _messages(key, count=2)
    sig = np.asarray(R.sign(m_dig, key))
    for i, msg in enumerate(msgs):
        assert L.limbs_to_int(sig[i], DIGIT_BITS) == pow(msg, key.d, key.n), i


def test_decrypt_crt_matches_plain(key):
    """CRT decrypt (two half-size ladders + Garner) == full ladder == the
    Python-int oracle; both compute c^d mod n."""
    msgs, c_dig = _messages(key)
    plain = np.asarray(R.sign(c_dig, key))            # c^d mod n, full ladder
    crt = np.asarray(R.decrypt_crt(c_dig, key))
    for i, msg in enumerate(msgs):
        want = pow(msg, key.d, key.n)
        assert L.limbs_to_int(crt[i], DIGIT_BITS) == want, i
        assert L.limbs_to_int(plain[i], DIGIT_BITS) == want, i


def test_decrypt_crt_requires_factors(key):
    pub = R.RSAKey(n=key.n, e=key.e, d=key.d, bits=key.bits)
    _, c_dig = _messages(key, count=1)
    with pytest.raises(ValueError, match="p, q"):
        R.decrypt_crt(c_dig, pub)


def test_tampered_signature_rejected(key):
    msgs, m_dig = _messages(key)
    sig = np.asarray(R.sign(m_dig, key)).copy()
    sig[:, 0] ^= 1                                    # flip one bit per lane
    back = np.asarray(R.verify(jnp.asarray(sig), key))
    for i, msg in enumerate(msgs):
        assert L.limbs_to_int(back[i], DIGIT_BITS) != msg % key.n, i


def test_verify_rejects_cross_lane_swap(key):
    """A valid signature for one message must not verify another."""
    msgs, m_dig = _messages(key)
    sig = np.asarray(R.sign(m_dig, key))
    swapped = jnp.asarray(np.roll(sig, 1, axis=0))
    back = np.asarray(R.verify(swapped, key))
    for i, msg in enumerate(msgs):
        assert L.limbs_to_int(back[i], DIGIT_BITS) != msg % key.n, i
