"""DoT addition/subtraction vs the Python-int oracle (random + pathological)."""
import numpy as np
import pytest

import repro.core.add as A
from repro.core import limbs as L

RNG = np.random.default_rng(0)

SIZES_BITS = [64, 128, 512, 1024, 2048]  # -> m = 2..64 limbs of 32 bits


def _check_add(fn, xs, ys, m, carry_in=0):
    a = L.ints_to_batch(xs, m)
    b = L.ints_to_batch(ys, m)
    s, c = fn(a, b)
    s = np.asarray(s)
    c = np.asarray(c)
    for i, (x, y) in enumerate(zip(xs, ys)):
        want = x + y
        got = L.limbs_to_int(s[i]) + (int(c[i]) << (32 * m))
        assert got == want, f"{fn.__name__} m={m}: {x} + {y}: got {got}"


def _check_sub(fn, xs, ys, m):
    a = L.ints_to_batch(xs, m)
    b = L.ints_to_batch(ys, m)
    d, bo = fn(a, b)
    d = np.asarray(d)
    bo = np.asarray(bo)
    mod = 1 << (32 * m)
    for i, (x, y) in enumerate(zip(xs, ys)):
        want = (x - y) % mod
        want_b = 1 if x < y else 0
        assert L.limbs_to_int(d[i]) == want
        assert int(bo[i]) == want_b


@pytest.mark.parametrize("strategy", sorted(A.ADD_STRATEGIES))
@pytest.mark.parametrize("nbits", SIZES_BITS)
def test_add_random(strategy, nbits):
    m = nbits // 32
    xs = L.random_bigints(RNG, 16, nbits)
    ys = L.random_bigints(RNG, 16, nbits)
    _check_add(A.ADD_STRATEGIES[strategy], xs, ys, m)


@pytest.mark.parametrize("strategy", sorted(A.ADD_STRATEGIES))
def test_add_pathological(strategy):
    nbits = 512
    m = nbits // 32
    pairs = L.pathological_pairs(nbits)
    xs = [p[0] for p in pairs]
    ys = [p[1] for p in pairs]
    _check_add(A.ADD_STRATEGIES[strategy], xs, ys, m)
    # and flipped, to hit the carry-in-dependent paths
    _check_add(A.ADD_STRATEGIES[strategy], ys, xs, m)


@pytest.mark.parametrize("strategy", sorted(A.SUB_STRATEGIES))
@pytest.mark.parametrize("nbits", SIZES_BITS)
def test_sub_random(strategy, nbits):
    m = nbits // 32
    xs = L.random_bigints(RNG, 16, nbits)
    ys = L.random_bigints(RNG, 16, nbits)
    _check_sub(A.SUB_STRATEGIES[strategy], xs, ys, m)


@pytest.mark.parametrize("strategy", sorted(A.SUB_STRATEGIES))
def test_sub_pathological(strategy):
    nbits = 512
    m = nbits // 32
    pairs = L.pathological_pairs(nbits)
    xs = [p[0] for p in pairs]
    ys = [p[1] for p in pairs]
    _check_sub(A.SUB_STRATEGIES[strategy], xs, ys, m)
    _check_sub(A.SUB_STRATEGIES[strategy], ys, xs, m)


def test_carry_in():
    m = 4
    full = (1 << 128) - 1
    a = L.ints_to_batch([full, 5], m)
    b = L.ints_to_batch([0, 7], m)
    s, c = A.dot_add(a, b, carry_in=1)
    assert L.limbs_to_int(np.asarray(s)[0]) == 0 and int(np.asarray(c)[0]) == 1
    assert L.limbs_to_int(np.asarray(s)[1]) == 13


def test_phase4_trigger_explicit():
    """Force the cascading-carry slow path (paper Phase 4)."""
    m = 8
    # a + b where the P3 carry addition overflows an intermediate max limb:
    # a = B-1 in limb1, b arranged so limb0 generates and limb1 == MAX after P1.
    x = (0xFFFFFFFF << 32) | 0xFFFFFFFF
    y = 1
    _check_add(A.dot_add, [x], [y], m)
    # long cascade: 256-bit all-ones + 1 within 8 limbs
    _check_add(A.dot_add, [(1 << 256) - 1], [1], m)
    _check_sub(A.dot_sub, [0], [1], m)
    _check_sub(A.dot_sub, [1 << 255], [1], m)


def test_batched_leading_axes():
    m = 4
    xs = L.random_bigints(RNG, 12, 128)
    ys = L.random_bigints(RNG, 12, 128)
    a = L.ints_to_batch(xs, m).reshape(3, 4, m)
    b = L.ints_to_batch(ys, m).reshape(3, 4, m)
    s, c = A.dot_add(a, b)
    assert s.shape == (3, 4, m) and c.shape == (3, 4)
    s2, c2 = A.dot_add(a.reshape(12, m), b.reshape(12, m))
    np.testing.assert_array_equal(np.asarray(s).reshape(12, m), np.asarray(s2))
