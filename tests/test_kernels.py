"""Per-kernel correctness: Pallas (interpret mode on CPU) vs the pure-jnp
oracle (ref.py), swept over shapes; oracles themselves are tested against
Python-int ground truth elsewhere."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_accum as EA
from repro.core import limbs as L
from repro.kernels.dot_add import ops as add_ops
from repro.kernels.dot_add import ref as add_ref
from repro.kernels.dot_mul import ops as mul_ops
from repro.kernels.dot_mul import ref as mul_ref
from repro.kernels.exact_accum import ops as ea_ops
from repro.kernels.exact_accum import ref as ea_ref

RNG = np.random.default_rng(3)


def _rand_limbs(batch, m):
    return RNG.integers(0, 1 << 32, (batch, m), dtype=np.uint64).astype(np.uint32)


@pytest.mark.parametrize("batch", [1, 7, 64, 300])
@pytest.mark.parametrize("m", [2, 8, 16, 64])
def test_dot_add_kernel_sweep(batch, m):
    a, b = _rand_limbs(batch, m), _rand_limbs(batch, m)
    s, c = add_ops.dot_add(a, b)
    s_r, c_r = add_ref.dot_add_ref(a, b)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_r))


@pytest.mark.parametrize("batch", [1, 33])
@pytest.mark.parametrize("m", [4, 16])
def test_dot_sub_kernel_sweep(batch, m):
    a, b = _rand_limbs(batch, m), _rand_limbs(batch, m)
    s, c = add_ops.dot_sub(a, b)
    s_r, c_r = add_ref.dot_sub_ref(a, b)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_r))


def test_dot_add_kernel_pathological():
    m = 16
    pairs = L.pathological_pairs(32 * m)
    a = L.ints_to_batch([p[0] for p in pairs], m)
    b = L.ints_to_batch([p[1] for p in pairs], m)
    s, c = add_ops.dot_add(a, b)
    for i, (x, y) in enumerate(pairs):
        got = L.limbs_to_int(np.asarray(s)[i]) + (int(np.asarray(c)[i]) << (32 * m))
        assert got == x + y


@pytest.mark.parametrize("batch", [1, 5, 40])
@pytest.mark.parametrize("nbits", [128, 256, 512])
def test_dot_mul_kernel_sweep(batch, nbits):
    m = nbits // 32
    a, b = _rand_limbs(batch, m), _rand_limbs(batch, m)
    p = mul_ops.dot_mul_limbs32(a, b)
    p_r = mul_ref.dot_mul_limbs32_ref(a, b)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_r))
    # spot-check vs python ints
    x = L.limbs_to_int(a[0])
    y = L.limbs_to_int(b[0])
    assert L.limbs_to_int(np.asarray(p)[0]) == x * y


def test_dot_mul_kernel_pathological():
    nbits = 256
    m = nbits // 32
    pairs = L.pathological_pairs(nbits)
    a = L.ints_to_batch([p[0] for p in pairs], m)
    b = L.ints_to_batch([p[1] for p in pairs], m)
    p = np.asarray(mul_ops.dot_mul_limbs32(a, b))
    for i, (x, y) in enumerate(pairs):
        assert L.limbs_to_int(p[i]) == x * y


@pytest.mark.parametrize("shape", [(17,), (64, 33), (256,), (1000,)])
def test_exact_accum_encode_finalize(shape):
    x = RNG.standard_normal(shape).astype(np.float32)
    d = ea_ops.encode(jnp.asarray(x))
    d_r = ea_ref.encode_ref(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d_r))
    y = ea_ops.finalize(d, shape=shape)
    y_r = ea_ref.finalize_ref(d_r, shape=shape)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_r))
    # quantization bound
    np.testing.assert_allclose(np.asarray(y), x, atol=2.0 ** -24)


def test_exact_accum_kernel_accumulate_matches_core():
    xs = RNG.standard_normal((20, 128)).astype(np.float32)
    acc = ea_ops.encode(jnp.asarray(xs[0]))
    for i in range(1, 20):
        acc = ea_ops.accumulate(acc, ea_ops.encode(jnp.asarray(xs[i])))
    y = np.asarray(ea_ops.finalize(acc, shape=(128,)))
    want = np.asarray(EA.exact_reduce(jnp.asarray(xs), 1))
    np.testing.assert_array_equal(y, want)
