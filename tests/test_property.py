"""Hypothesis property-based tests on the system's core invariants.

Two layers: per-op oracles against Python ints (the original suite) and
the CROSS-OP algebraic consistency suite -- ring identities whose two
sides are deliberately computed through DIFFERENT backends (dot vs
schoolbook vs karatsuba vs ntt multiplies, Montgomery vs Barrett
modexp, mul vs divmod), so the paths are cross-checked against each
other rather than only against the shared python-int oracle.  A bug
that two backends share with the conversion glue would slip past
per-op oracles; it cannot slip past an identity whose sides never meet
until the final compare.

hypothesis is a dev-only dependency (``pip install -e .[dev]``); a bare
environment skips this module instead of erroring at collection.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.add as A
import repro.core.modular as MOD
import repro.core.mul as M
from repro.core import div as DV
from repro.core import exact_accum as EA
from repro.core import limbs as L

SET = settings(max_examples=40, deadline=None)


def bigint(nbits):
    return st.integers(min_value=0, max_value=(1 << nbits) - 1)


@given(st.integers(1, 12).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET
def test_dot_add_matches_python(args):
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    s, c = A.dot_add(a, b)
    assert L.limbs_to_int(np.asarray(s)[0]) + (int(np.asarray(c)[0]) << (32 * m)) == x + y


@given(st.integers(1, 12).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET
def test_dot_sub_matches_python(args):
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    d, bo = A.dot_sub(a, b)
    assert L.limbs_to_int(np.asarray(d)[0]) == (x - y) % (1 << (32 * m))
    assert int(np.asarray(bo)[0]) == (1 if x < y else 0)


@given(st.integers(1, 8).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET
def test_mul_matches_python(args):
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    p = M.mul_limbs32(a, b, method="dot")
    assert L.limbs_to_int(np.asarray(p)[0]) == x * y


@given(st.integers(1, 6).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m), bigint(32 * m))))
@SET
def test_mul_distributes_over_add(args):
    """(x + y) * z == x*z + y*z  -- ring axioms survive the limb domain."""
    m, x, y, z = args
    mod = 1 << (64 * m)
    a = L.ints_to_batch([(x + y) % (1 << (32 * m))], m)
    zz = L.ints_to_batch([z], m)
    lhs = L.limbs_to_int(np.asarray(M.mul_limbs32(a, zz))[0])
    want = (((x + y) % (1 << (32 * m))) * z) % mod
    assert lhs == want


@given(st.lists(st.floats(-32, 32, allow_nan=False, width=32),
                min_size=2, max_size=48),
       st.randoms(use_true_random=False))
@SET
def test_exact_accum_order_invariance(vals, rnd):
    """Sum of encoded values is bitwise identical under any permutation."""
    x = np.array(vals, np.float32)
    perm = list(range(len(x)))
    rnd.shuffle(perm)
    d1 = EA.encode(jnp.asarray(x)).sum(axis=0)
    d2 = EA.encode(jnp.asarray(x[perm])).sum(axis=0)
    y1 = np.asarray(EA.decode(EA.normalize(d1), EA.DEFAULT))
    y2 = np.asarray(EA.decode(EA.normalize(d2), EA.DEFAULT))
    assert y1.tobytes() == y2.tobytes()


@given(st.integers(2, 10).flatmap(
    lambda m: st.tuples(st.just(m), bigint(16 * m))))
@SET
def test_split_join_roundtrip(args):
    m, x = args
    a = L.ints_to_batch([x], m)
    for bits in (7, 11, 16):
        d = M.split_digits(jnp.asarray(a), bits)
        back = M.join_digits(d, bits, m)
        np.testing.assert_array_equal(np.asarray(back), a)


# ===========================================================================
# Cross-op algebraic consistency suite: each identity's sides run through
# DIFFERENT backends, so the paths check each other, not just python-int.
# ===========================================================================

# the jnp compositions plus the NTT kernel family; every call below goes
# through a jitted entry point (M.mul_jit / a jitted divmod) and the
# width draws are sampled from a FIXED handful so shapes repeat across
# hypothesis examples and the jit cache pays the trace cost exactly once
MIXED_MUL_METHODS = ("dot", "schoolbook", "karatsuba", "ntt")
CROSS_WIDTHS = (2, 3, 6)                       # 64/96/192-bit operands

SET_CROSS = settings(max_examples=25, deadline=None)

_divmod_jit = DV.divmod_jit                    # jitted divmod front door


@given(st.sampled_from(CROSS_WIDTHS).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET_CROSS
def test_cross_mul_backends_agree_and_divmod_inverts(args):
    """All multiply backends produce identical products, and
    divmod(a*b, b) == (a, 0) with the division subsystem (the divmod
    rides the Newton-reciprocal path, itself built on pipeline
    multiplies -- mul and div cross-check each other)."""
    m, x, y = args
    y |= 1                                     # nonzero divisor
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    prods = {meth: np.asarray(M.mul_jit(a, b, meth))
             for meth in MIXED_MUL_METHODS}
    ref = prods[MIXED_MUL_METHODS[0]]
    for meth, p in prods.items():
        np.testing.assert_array_equal(p, ref, err_msg=meth)
    q, r = _divmod_jit(jnp.asarray(prods["ntt"]), jnp.asarray(b))
    assert L.limbs_to_int(np.asarray(q)[0]) == x
    assert L.limbs_to_int(np.asarray(r)[0]) == 0


@given(st.integers(1, 8).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET_CROSS
def test_cross_add_sub_roundtrip(args):
    """(x + y) - y == x, and the subtract's borrow mirrors the add's
    carry (the DoT add and sub lanes invert each other exactly)."""
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    s, c = A.dot_add(a, b)
    d, bo = A.dot_sub(s, b)
    np.testing.assert_array_equal(np.asarray(d), a)
    assert int(np.asarray(bo)[0]) == int(np.asarray(c)[0])


@given(st.sampled_from((2, 5)).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m),
                        bigint(32 * m))))
@SET_CROSS
def test_cross_distributivity_mixed_backends(args):
    """a*(b+c) == a*b + a*c with the left side through the NTT kernel
    and the right side through the jnp VnC composition, recombined
    under ONE carry-resolving dot_add."""
    m, x, y, z = args
    w = m + 1                                  # headroom for y + z
    a_w = L.ints_to_batch([x], w)
    s_w = L.ints_to_batch([y + z], w)
    lhs = np.asarray(M.mul_jit(a_w, s_w, "ntt"))
    p1 = M.mul_jit(L.ints_to_batch([x], m), L.ints_to_batch([y], m), "dot")
    p2 = M.mul_jit(L.ints_to_batch([x], m), L.ints_to_batch([z], m), "dot")
    pad = [(0, 0), (0, 2 * w - 2 * m)]
    rhs, carry = A.dot_add(jnp.pad(p1, pad), jnp.pad(p2, pad))
    assert int(np.asarray(carry)[0]) == 0      # 2w limbs always suffice
    np.testing.assert_array_equal(lhs, np.asarray(rhs))


# Fermat's little theorem: a^(p-1) == 1 mod p, Montgomery ladder vs
# Barrett ladder -- the two modexp reductions check each other AND the
# known answer.  Fixed primes keep every shape jit-cached.
FERMAT_PRIMES = (
    0xD59741E7F4DE438F5D411B0DF9E324DF,                    # 128-bit
    0xB7CFD8913CE3808E345158DB971503BD126D15699C9E8753,    # 192-bit
)
_FERMAT_FNS = {}


def _fermat_fn(p, backend):
    if (p, backend) not in _FERMAT_FNS:
        ctx = MOD.mont_setup(p)
        bits = jnp.asarray(MOD.exp_bits_msb(p - 1, p.bit_length()))
        _FERMAT_FNS[(p, backend)] = jax.jit(
            lambda xd: MOD.mod_exp(xd, bits, ctx, backend=backend))
    return _FERMAT_FNS[(p, backend)]


@given(st.sampled_from(FERMAT_PRIMES), st.integers(2, (1 << 128) - 1))
@settings(max_examples=15, deadline=None)
def test_cross_fermat_little_theorem(p, a):
    a = a % p or 2                             # nonzero residue
    m_digits = MOD.mont_setup(p).m
    x = jnp.asarray(L.ints_to_batch([a], m_digits, 16))
    got = {be: np.asarray(_fermat_fn(p, be)(x))
           for be in ("jnp", "barrett", "barrett_fused")}
    for be, out in got.items():
        assert L.limbs_to_int(out[0], 16) == 1, (be, hex(p), hex(a))
