"""Hypothesis property-based tests on the system's core invariants.

hypothesis is a dev-only dependency (``pip install -e .[dev]``); a bare
environment skips this module instead of erroring at collection.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.add as A
import repro.core.mul as M
from repro.core import exact_accum as EA
from repro.core import limbs as L

SET = settings(max_examples=40, deadline=None)


def bigint(nbits):
    return st.integers(min_value=0, max_value=(1 << nbits) - 1)


@given(st.integers(1, 12).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET
def test_dot_add_matches_python(args):
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    s, c = A.dot_add(a, b)
    assert L.limbs_to_int(np.asarray(s)[0]) + (int(np.asarray(c)[0]) << (32 * m)) == x + y


@given(st.integers(1, 12).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET
def test_dot_sub_matches_python(args):
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    d, bo = A.dot_sub(a, b)
    assert L.limbs_to_int(np.asarray(d)[0]) == (x - y) % (1 << (32 * m))
    assert int(np.asarray(bo)[0]) == (1 if x < y else 0)


@given(st.integers(1, 8).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m))))
@SET
def test_mul_matches_python(args):
    m, x, y = args
    a = L.ints_to_batch([x], m)
    b = L.ints_to_batch([y], m)
    p = M.mul_limbs32(a, b, method="dot")
    assert L.limbs_to_int(np.asarray(p)[0]) == x * y


@given(st.integers(1, 6).flatmap(
    lambda m: st.tuples(st.just(m), bigint(32 * m), bigint(32 * m), bigint(32 * m))))
@SET
def test_mul_distributes_over_add(args):
    """(x + y) * z == x*z + y*z  -- ring axioms survive the limb domain."""
    m, x, y, z = args
    mod = 1 << (64 * m)
    a = L.ints_to_batch([(x + y) % (1 << (32 * m))], m)
    zz = L.ints_to_batch([z], m)
    lhs = L.limbs_to_int(np.asarray(M.mul_limbs32(a, zz))[0])
    want = (((x + y) % (1 << (32 * m))) * z) % mod
    assert lhs == want


@given(st.lists(st.floats(-32, 32, allow_nan=False, width=32),
                min_size=2, max_size=48),
       st.randoms(use_true_random=False))
@SET
def test_exact_accum_order_invariance(vals, rnd):
    """Sum of encoded values is bitwise identical under any permutation."""
    x = np.array(vals, np.float32)
    perm = list(range(len(x)))
    rnd.shuffle(perm)
    d1 = EA.encode(jnp.asarray(x)).sum(axis=0)
    d2 = EA.encode(jnp.asarray(x[perm])).sum(axis=0)
    y1 = np.asarray(EA.decode(EA.normalize(d1), EA.DEFAULT))
    y2 = np.asarray(EA.decode(EA.normalize(d2), EA.DEFAULT))
    assert y1.tobytes() == y2.tobytes()


@given(st.integers(2, 10).flatmap(
    lambda m: st.tuples(st.just(m), bigint(16 * m))))
@SET
def test_split_join_roundtrip(args):
    m, x = args
    a = L.ints_to_batch([x], m)
    for bits in (7, 11, 16):
        d = M.split_digits(jnp.asarray(a), bits)
        back = M.join_digits(d, bits, m)
        np.testing.assert_array_equal(np.asarray(back), a)
