"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus a prefill+decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        toks = rng.integers(0, cfg.vocab_size, (B, S - n_img)).astype(np.int32)
        batch = {
            "tokens": jnp.asarray(toks),
            "image_embeds": jnp.asarray(
                rng.standard_normal((B, n_img, cfg.d_model)), cfg.cdtype),
            "targets": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
            "loss_mask": jnp.asarray(
                np.concatenate([np.zeros((B, n_img)), np.ones((B, S - n_img))],
                               axis=1).astype(np.float32)),
        }
        return batch
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
    }
    if cfg.family == "audio":
        te = S // cfg.enc_frames_ratio
        batch["audio_frames"] = jnp.asarray(
            rng.standard_normal((B, te, cfg.d_model)), cfg.cdtype)
    return batch


def zero_cache(model, B, S_cache):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        model.cache_specs(B, S_cache))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    rng = np.random.default_rng(42)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, rng)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one gradient step
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gn = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grad norm {gn}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    rng = np.random.default_rng(7)
    params = model.init(jax.random.key(1))
    batch = make_batch(cfg, rng)
    batch.pop("targets", None)
    batch.pop("loss_mask", None)

    S_cache = 2 * S
    cache = zero_cache(model, B, S_cache)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch} prefill NaN"

    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(
        params, cache, tok[:, None], jnp.int32(S))
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), f"{arch} decode NaN"
