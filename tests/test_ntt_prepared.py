"""Prepared-operand NTT cache: bit-identity with the plain path, LRU
bookkeeping, and the memoized modular setups that feed it.

The prepared path (kernels/ntt_mul.ntt_mul_digits_prepared) skips one of
the two forward transforms by caching the per-prime forward NTT of a
host-known constant; these tests pin that the shortcut is BIT-IDENTICAL
to the plain kernel (same butterflies, same Montgomery domain, so
equality is exact, not approximate), that the LRU keying/eviction is
sound, and that a disabled cache (capacity 0) routes callers back to the
plain path untouched.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.core import div as DV
from repro.core import limbs as L
from repro.core import modular as M
from repro.kernels.ntt_mul import ops as NO

RNG = np.random.default_rng(23)
DIGIT_BITS = 16


def _rand_int(bits):
    return int(L.random_bigints(RNG, 1, bits)[0]) | (1 << (bits - 1))


def _digits(ints, m, bits=DIGIT_BITS):
    return jnp.asarray(np.stack([L.int_to_limbs(v, m, bits) for v in ints]))


@pytest.fixture(autouse=True)
def _fresh_cache():
    NO.clear_operand_cache()
    yield
    NO.clear_operand_cache()


# ---------------------------------------------------------------------------
# bit-identity: prepared vs plain vs python-int oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nprimes", [2, 3])
def test_prepared_bit_identical_both_prime_sets(nprimes):
    nd = 64
    bits = nd * DIGIT_BITS
    a_ints = [_rand_int(bits) for _ in range(3)]
    b_int = _rand_int(bits)
    a = _digits(a_ints, nd)
    b = _digits([b_int] * 3, nd)
    plain = np.asarray(NO.ntt_mul_digits(a, b, nprimes=nprimes))
    prep = np.asarray(NO.ntt_mul_digits_prepared(a, b_int, nprimes=nprimes))
    np.testing.assert_array_equal(prep, plain)
    for i, ai in enumerate(a_ints):
        assert L.limbs_to_int(prep[i], DIGIT_BITS) == ai * b_int, i
    stats = NO.operand_cache_stats()
    # one entry holds ALL per-prime rows for a (value, prime set, N) key
    assert stats["misses"] == 1 and stats["entries"] == 1


@pytest.mark.parametrize("digit_bits", [8, 16])
def test_prepared_through_pipeline_digit_bits(digit_bits):
    """mul_digits_via_pipeline repacks any digit radix to 32-bit limbs
    before dispatch, so b_const must give identical results at radix
    2**8 and 2**16, cached AND uncached."""
    nd32 = 64                                   # 1024-bit operands
    bits = nd32 * 32
    nd = bits // digit_bits
    a_int, b_int = _rand_int(bits), _rand_int(bits)
    a = _digits([a_int], nd, digit_bits)
    b = _digits([b_int], nd, digit_bits)
    with api.configure(mul_method="ntt"):
        cached = np.asarray(DV._mul_equalized(a, b, digit_bits,
                                              b_const=b_int))
        assert NO.operand_cache_stats()["misses"] > 0
        with api.configure(ntt_cache_entries=0):
            uncached = np.asarray(DV._mul_equalized(a, b, digit_bits,
                                                    b_const=b_int))
    np.testing.assert_array_equal(cached, uncached)
    assert L.limbs_to_int(cached[0], digit_bits) == a_int * b_int


def test_capacity_zero_disables_prepared_path():
    """ntt_cache_entries=0 is the A/B switch: b_const callers must fall
    back to the plain two-transform kernel, leaving the cache cold."""
    from repro.core.mul import mul_limbs32

    bits = 1024
    a_int, b_int = _rand_int(bits), _rand_int(bits)
    a32 = jnp.asarray(L.int_to_limbs(a_int, bits // 32, 32))[None, :]
    b32 = jnp.asarray(L.int_to_limbs(b_int, bits // 32, 32))[None, :]
    with api.configure(ntt_cache_entries=0):
        out = np.asarray(mul_limbs32(a32, b32, method="ntt",
                                     b_const=b_int))
        stats = NO.operand_cache_stats()
    assert stats == {"hits": 0, "misses": 0, "evictions": 0,
                     "entries": 0, "capacity": 0}
    assert L.limbs_to_int(out[0], 32) == a_int * b_int


# ---------------------------------------------------------------------------
# LRU bookkeeping: keying, hits, eviction order
# ---------------------------------------------------------------------------

def test_cache_key_isolation():
    """Distinct values, prime sets, and transform lengths must occupy
    DISTINCT entries -- a collision would silently corrupt products."""
    n = 256
    v1, v2 = _rand_int(1024), _rand_int(1024)
    r_v1_p2 = NO.prepared_operand(v1, n, 2)
    r_v2_p2 = NO.prepared_operand(v2, n, 2)
    r_v1_p3 = NO.prepared_operand(v1, n, 3)
    r_v1_n512 = NO.prepared_operand(v1, 512, 2)
    assert NO.operand_cache_stats()["entries"] == 4
    assert len(r_v1_p2) == 2 and len(r_v1_p3) == 3
    assert r_v1_p2[0].shape == (1, n) and r_v1_n512[0].shape == (1, 512)
    assert not np.array_equal(np.asarray(r_v1_p2[0]),
                              np.asarray(r_v2_p2[0]))
    # same key -> same cached rows, counted as a hit
    again = NO.prepared_operand(v1, n, 2)
    assert again is r_v1_p2
    assert NO.operand_cache_stats()["hits"] == 1


def test_eviction_order_lru():
    """Capacity-2 cache: touching an old entry protects it; the LEAST
    recently used entry is the one evicted."""
    n = 128
    v1, v2, v3 = (_rand_int(512) for _ in range(3))
    with api.configure(ntt_cache_entries=2):
        NO.prepared_operand(v1, n, 2)
        NO.prepared_operand(v2, n, 2)
        NO.prepared_operand(v1, n, 2)           # refresh v1: v2 is now LRU
        NO.prepared_operand(v3, n, 2)           # evicts v2, not v1
        stats = NO.operand_cache_stats()
        assert stats["entries"] == 2 and stats["evictions"] == 1
        assert (v1, 2, n) in NO._prepared_cache
        assert (v2, 2, n) not in NO._prepared_cache
        assert (v3, 2, n) in NO._prepared_cache
        NO.prepared_operand(v1, n, 2)           # still resident: a hit
        assert NO.operand_cache_stats()["hits"] == 2
        NO.prepared_operand(v2, n, 2)           # evicted: a fresh miss
        assert NO.operand_cache_stats()["misses"] == 4


def test_miss_inside_trace_caches_concrete_rows():
    """A cache miss can happen WHILE an outer jit is tracing (the first
    trace of a b_const divmod).  The rows stored then must be concrete
    host arrays, not that trace's tracers -- a poisoned entry would
    crash every later eager caller with UnexpectedTracerError."""
    import jax

    nd = 64
    bits = nd * DIGIT_BITS
    a_int, b_int = _rand_int(bits), _rand_int(bits)
    a = _digits([a_int], nd)

    traced = jax.jit(
        lambda x: NO.ntt_mul_digits_prepared(x, b_int))(a)
    assert NO.operand_cache_stats()["misses"] == 1
    for rows in NO._prepared_cache.values():
        for r in rows:
            assert isinstance(r, jax.Array)
            np.asarray(r)                    # concretizable: not a tracer
    # eager call reusing the entry populated during the trace
    eager = NO.ntt_mul_digits_prepared(a, b_int)
    assert NO.operand_cache_stats()["hits"] == 1
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(traced))
    assert L.limbs_to_int(np.asarray(eager)[0], DIGIT_BITS) == a_int * b_int


def test_configure_rejects_bad_capacity():
    with pytest.raises(ValueError, match="ntt_cache_entries"):
        api.configure(ntt_cache_entries=-1)
    with pytest.raises(ValueError, match="ntt_cache_entries"):
        api.configure(ntt_cache_entries="lots")


def test_cache_stats_facade_shape():
    stats = api.cache_stats()
    assert set(stats) == {"twiddle", "operand", "autotune", "ctx"}
    for name in ("twiddle", "operand", "autotune"):
        assert {"hits", "misses"} <= set(stats[name])
    # ctx nests one hits/misses block per memoized modular setup
    assert set(stats["ctx"]) == {"mont_setup", "barrett_setup"}
    for section in stats["ctx"].values():
        assert {"hits", "misses"} <= set(section)
    assert stats["operand"]["capacity"] == NO.operand_cache_capacity()


# ---------------------------------------------------------------------------
# memoized modular setups (the constants that FEED the operand cache)
# ---------------------------------------------------------------------------

def test_modular_setups_memoized():
    n = _rand_int(512) | 1
    assert M.mont_setup(n, 512) is M.mont_setup(n, 512)
    assert M.barrett_setup(n, 512) is M.barrett_setup(n, 512)
    ctx = M.mont_setup(n, 512)
    # _as_barrett promotes a MontCtx on EVERY Barrett-path call; the
    # promotion must be a cache hit, not a fresh B**2m // n division
    assert M._as_barrett(ctx) is M._as_barrett(ctx)
    bctx = M._as_barrett(ctx)
    assert bctx.mu == (1 << (32 * 32)) // n     # B**2m, m = 32 digits
