"""repro.obs: metrics registry math, dispatch tracing from the real
tier choosers, span profiling / Chrome-trace export, the retrace alarm
on the serving engine, and the disabled-mode zero-overhead contract.

Everything here is host-side (the dispatchers run without launching a
kernel; the engine tests stub ``_execute``), so the module adds
seconds, not minutes, to tier 1."""
import json
import random
import warnings

import numpy as np
import pytest

from repro import api, obs
from repro.configs.dot_bignum import ServeConfig, pick_modexp_window
from repro.core.div import select_div_method
from repro.core.modular import select_modexp_backend
from repro.core.mul import select_method
from repro.obs import metrics as M
from repro.obs import retrace as RT
from repro.serve import bignum_engine as BE

PY = random.Random(7)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with empty buffers and obs off."""
    obs.reset()
    yield
    obs.reset()
    obs.disable()


def _observing():
    return api.configure(observability=True)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_series_and_total():
    c = M.Counter("c")
    c.inc(op="mul", choice="ntt")
    c.inc(2, op="mul", choice="dot")
    c.inc(op="div", choice="recip")
    assert c.value(op="mul", choice="ntt") == 1
    assert c.value(op="mul", choice="dot") == 2
    assert c.value(op="mul") == 0            # exact label set, not filter
    assert c.total(op="mul") == 3            # filter sums matching series
    assert c.total() == 4
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)


def test_gauge_last_write_wins():
    g = M.Gauge("g")
    assert g.value(q="depth") is None
    g.set(3, q="depth")
    g.set(1, q="depth")
    assert g.value(q="depth") == 1


def test_histogram_quantiles_uniform_stream():
    # 1..100 into unit-width buckets: interpolation is exact for every
    # percentile of a uniform stream (within one bucket width)
    h = M.Histogram("h", bounds=tuple(float(b) for b in range(1, 101)))
    for v in range(1, 101):
        h.observe(v)
    assert h.count() == 100
    assert h.quantile(0.0) == 1.0            # clamped to observed min
    assert h.quantile(1.0) == 100.0          # clamped to observed max
    for q in (0.25, 0.5, 0.9, 0.95, 0.99):
        got = h.quantile(q)
        want = float(np.percentile(np.arange(1, 101), q * 100))
        assert abs(got - want) <= 1.0, (q, got, want)


def test_histogram_single_value_stream_is_exact():
    h = M.Histogram("h1")                    # default latency bounds
    for _ in range(5):
        h.observe(0.003)
    for q in (0.5, 0.95, 0.99):
        assert h.quantile(q) == pytest.approx(0.003)
    snap = h.snapshot()[""]
    assert snap["count"] == 5
    assert snap["p99"] == pytest.approx(0.003)
    assert snap["min"] == snap["max"] == pytest.approx(0.003)


def test_histogram_overflow_bucket():
    h = M.Histogram("h2", bounds=(1.0, 2.0))
    h.observe(0.5)
    h.observe(1000.0)                        # overflow bucket
    assert h.count() == 2
    assert h.quantile(1.0) == 1000.0
    # interpolated within the owning bucket, clamped to observed range
    assert 0.5 <= h.quantile(0.25) <= 1.0


def test_histogram_empty_and_bad_args():
    h = M.Histogram("h3")
    assert h.quantile(0.5) is None
    with pytest.raises(ValueError, match="in \\[0, 1\\]"):
        h.quantile(1.5)
    with pytest.raises(ValueError, match="ascending"):
        M.Histogram("bad", bounds=(2.0, 1.0))


def test_registry_get_or_create_and_kind_mismatch():
    r = M.Registry()
    c1 = r.counter("x")
    assert r.counter("x") is c1
    with pytest.raises(TypeError, match="already registered"):
        r.gauge("x")
    c1.inc(a=1)
    r.histogram("lat").observe(0.01, op="t")
    snap = r.snapshot()
    assert snap["counters"]["x"] == {"a=1": 1}
    assert snap["histograms"]["lat"]["op=t"]["count"] == 1
    json.dumps(snap)                         # JSON-serializable contract
    r.reset()
    assert r.get("x") is None


# ---------------------------------------------------------------------------
# dispatch tracing (through the REAL dispatchers)
# ---------------------------------------------------------------------------

def test_dispatch_events_from_all_choosers():
    with _observing():
        assert select_method(8192, batch=8) == "ntt"
        assert select_div_method(256, 256, batch=8) == "schoolbook"
        assert select_modexp_backend(256, batch=8, ebits=64) == "pallas"
        pick_modexp_window(17)
    by_disp = {e.dispatcher: e for e in obs.dispatch_events()}
    assert set(by_disp) == {"mul", "div", "modexp", "modexp_window"}
    ev = by_disp["mul"]
    assert (ev.nbits, ev.batch, ev.choice) == (8192, 8, "ntt")
    assert ev.rule == "ntt_min_bits"         # WHICH threshold fired
    assert dict(by_disp["modexp"].detail)["ebits"] == 64
    assert by_disp["modexp_window"].choice == "2"   # e=65537 -> w=2
    # the dispatch_total counter ticked one series per decision
    c = obs.REGISTRY.get("dispatch_total")
    assert c.value(dispatcher="mul", choice="ntt") == 1
    assert c.total() == 4


def test_dispatch_override_rule_is_visible():
    with api.configure(mul_method="karatsuba", observability=True):
        assert select_method(64, batch=1) == "karatsuba"
    (ev,) = obs.dispatch_events("mul")
    assert ev.rule == "override"


def test_dispatch_report_aggregates_and_formats():
    with _observing():
        for _ in range(3):
            select_method(1024, batch=16)
    rows = api.dispatch_report()
    (row,) = [r for r in rows if r["dispatcher"] == "mul"]
    assert row["count"] == 3 and row["choice"] == "pallas_kara"
    text = "\n".join(obs.format_report())
    assert "[mul]" in text and "pallas_kara" in text and "x3" in text


def test_trace_subscribe_and_capacity():
    seen = []
    unsub = obs.subscribe(seen.append)
    try:
        with _observing():
            select_method(64, batch=1)
        assert len(seen) == 1 and seen[0].dispatcher == "mul"
    finally:
        unsub()
    obs.trace.set_capacity(2)
    try:
        with _observing():
            for _ in range(5):
                select_method(64, batch=1)
        assert len(obs.dispatch_events()) == 2   # ring buffer bounded
    finally:
        obs.trace.set_capacity(obs.trace.DEFAULT_CAPACITY)


def test_disabled_mode_no_events_no_metrics():
    # observability off (the default): dispatchers answer normally but
    # allocate NO events and tick NO metrics -- the near-zero-cost path
    assert not obs.enabled()
    assert select_method(8192, batch=8) == "ntt"
    select_div_method(256, 256, batch=8)
    select_modexp_backend(256, batch=8, ebits=64)
    pick_modexp_window(17)
    assert obs.dispatch_events() == []
    assert obs.spans.spans() == []
    with obs.span("nothing", cat="execute"):
        pass
    assert obs.spans.spans() == []
    assert obs.REGISTRY.names() == []        # registry untouched


# ---------------------------------------------------------------------------
# spans / Chrome trace
# ---------------------------------------------------------------------------

def test_span_records_and_chrome_trace(tmp_path):
    with _observing():
        with obs.span("compile", cat="trace", bits=256):
            pass
        obs.spans.record("exec", "execute", 0.0, 0.25, batch=4)
        with pytest.raises(ValueError, match="choose from"):
            obs.spans.record("bad", "nope", 0.0, 1.0)
    spans = obs.spans.spans()
    assert [s["cat"] for s in spans] == ["trace", "execute"]
    assert obs.spans.total_seconds("execute") == pytest.approx(0.25)
    path = obs.write_chrome_trace(str(tmp_path / "t.json"))
    doc = json.loads(open(path).read())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for e in evs:
        assert e["ph"] == "X" and {"name", "cat", "ts", "dur",
                                   "pid", "tid"} <= set(e)
    # categories land on distinct tids so the viewer separates them
    assert {e["tid"] for e in evs} == {1, 2}
    assert evs[1]["dur"] == pytest.approx(0.25e6)    # microseconds


# ---------------------------------------------------------------------------
# serving engine: flush metrics + the retrace alarm
# ---------------------------------------------------------------------------

def _odd(bits):
    return PY.getrandbits(bits) | 1 | (1 << (bits - 1))


def _req(rid, n, e=65537):
    return BE.BignumRequest(rid=rid, op="mod_exp",
                            value=api.to_limbs(2, n.bit_length()),
                            modulus=n, exponent=e)


SMALL = ServeConfig(bucket_bits=(96, 160), exp_bucket_bits=(16, 32, 64),
                    slots=4, max_wait_s=0.02)


def _stub_engine():
    eng = BE.BignumEngine(SMALL)
    eng._execute = lambda bkey, reqs: np.zeros((SMALL.slots, 5), np.uint32)
    return eng


def test_engine_flush_populates_latency_histogram():
    eng = _stub_engine()
    n = _odd(90)
    with _observing():
        done = []
        for i in range(SMALL.slots):
            done += eng.submit(_req(i, n), now=0.001 * i)
    assert len(done) == SMALL.slots          # full flush
    h = obs.REGISTRY.get("serve_request_latency_seconds")
    assert h.count(op="mod_exp", bits=96) == SMALL.slots
    # every latency >= its queue wait; oldest request waited longest
    assert h.quantile(1.0, op="mod_exp", bits=96) >= 0.003
    c = obs.REGISTRY.get("serve_requests_total")
    assert c.value(op="mod_exp", bits=96) == SMALL.slots
    assert obs.REGISTRY.get("serve_batches_total").value(
        op="mod_exp", bits=96, reason="full") == 1
    assert obs.REGISTRY.get("serve_queue_depth").value() == 0
    (sp,) = obs.spans.spans()
    assert sp["name"] == "serve/mod_exp/96"
    assert sp["args"] == {"batch": SMALL.slots, "reason": "full"}


def test_engine_padded_lanes_and_deadline_reason():
    eng = _stub_engine()
    n = _odd(90)
    with _observing():
        assert eng.submit(_req(0, n), now=0.0) == []
        done = eng.flush_next_due(now=1.0)
    assert len(done) == 1
    assert obs.REGISTRY.get("serve_padded_lanes_total").value(
        op="mod_exp", bits=96) == SMALL.slots - 1
    assert obs.REGISTRY.get("serve_batches_total").value(
        op="mod_exp", bits=96, reason="deadline") == 1


def test_engine_disabled_mode_serves_without_metrics():
    eng = _stub_engine()
    n = _odd(90)
    done = []
    for i in range(SMALL.slots):
        done += eng.submit(_req(i, n), now=0.0)
    assert len(done) == SMALL.slots
    assert obs.REGISTRY.names() == []
    assert obs.spans.spans() == []
    assert eng.stats.served == SMALL.slots   # EngineStats still tick


def test_retrace_alarm_on_new_shape_after_warm():
    # real jit bodies (jnp backend, tiny widths): warming one bucket
    # then serving a DIFFERENT bucket forces a fresh trace -> alarm
    eng = BE.BignumEngine(SMALL, backend="jnp")
    n_small, n_big = _odd(90), _odd(150)
    eng.warm("mod_exp", modulus=n_small, exponent=65537)
    assert eng._warmed and RT.count("serve") == 0
    before = RT.count()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for i in range(SMALL.slots):         # new 160-bit bucket: traces
            eng.submit(_req(i, n_big), now=0.0)
    assert RT.count("serve") - before == 1
    assert RT.count("serve", op="mod_exp", bits=160) == 1
    assert any(isinstance(x.message, obs.RetraceWarning) for x in w)
    # the warmed bucket itself replays silently (jit cache hit)
    before = RT.count()
    with warnings.catch_warnings():
        warnings.simplefilter("error", obs.RetraceWarning)
        for i in range(SMALL.slots):
            eng.submit(_req(10 + i, n_small), now=0.0)
    assert RT.count() == before


def test_retrace_policy_raise_and_ignore():
    eng = _stub_engine()
    eng._warmed = True
    with api.configure(on_retrace="raise"):
        with pytest.raises(obs.RetraceAlarm, match="zero-retrace"):
            eng._on_trace("mod_exp", 96)
    with api.configure(on_retrace="ignore"):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            eng._on_trace("mod_exp", 96)     # counts, stays silent
    assert RT.count("serve") == 2            # metric ticks regardless
    with pytest.raises(ValueError, match="on_retrace"):
        api.configure(on_retrace="panic")


def test_multiple_warms_do_not_false_alarm():
    eng = BE.BignumEngine(SMALL, backend="jnp")
    with warnings.catch_warnings():
        warnings.simplefilter("error", obs.RetraceWarning)
        eng.warm("mod_exp", modulus=_odd(90), exponent=65537)
        eng.warm("mod_exp", modulus=_odd(150), exponent=65537)
    assert RT.count("serve") == 0


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_api_metrics_shape_and_cache_stats_ctx():
    snap = api.metrics()
    assert set(snap) >= {"counters", "gauges", "histograms", "caches"}
    ctx = snap["caches"]["ctx"]
    assert set(ctx) == {"mont_setup", "barrett_setup"}
    for c in ctx.values():
        assert {"hits", "misses", "entries", "capacity"} <= set(c)
    json.dumps(snap, default=str)
    # mont_setup memoization is visible through the ctx counters
    n = _odd(90)
    h0 = api.cache_stats()["ctx"]["mont_setup"]
    api.mod_setup(n, 96)
    api.mod_setup(n, 96)
    h1 = api.cache_stats()["ctx"]["mont_setup"]
    assert h1["misses"] == h0["misses"] + 1
    assert h1["hits"] >= h0["hits"] + 1


def test_configure_observability_validation():
    with pytest.raises(ValueError, match="observability"):
        api.configure(observability="yes")
    with api.configure(observability=True):
        assert obs.enabled()
    assert not obs.enabled()                 # context form restores
