"""Validate the multi-pod dry-run artifacts (deliverable e/g).

Skipped when experiments/dryrun is absent (fresh clone); after
`python -m repro.launch.dryrun --all` this asserts:
  * every (arch x applicable shape) cell compiled OK on BOTH meshes,
  * segment-split variants exist for the roofline correction,
  * per-chip argument bytes fit v5e HBM (16 GB),
  * roofline terms are computable for every single-pod cell.
"""
import json
import pathlib

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import applicable_shapes

DRY = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(
    not DRY.exists() or not any(DRY.glob("*.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun --all)")


def _load(arch, shape, mesh, variant):
    p = DRY / f"{arch}.{shape}.{mesh}.{variant}.json"
    assert p.exists(), f"missing dry-run cell {p.name}"
    rec = json.loads(p.read_text())
    assert rec.get("ok"), f"{p.name} failed: {rec.get('error')}"
    return rec


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_all_cells_compiled_on_both_meshes(arch):
    cfg = get_config(arch)
    for shape in applicable_shapes(cfg):
        single = _load(arch, shape.name, "single", "base")
        multi = _load(arch, shape.name, "multi", "base")
        assert single["cost"]["flops"] > 0
        assert multi["cost"]["flops"] > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_split_variants_exist_for_roofline(arch):
    cfg = get_config(arch)
    variants = (["split_enc", "split_dec"] if cfg.family == "audio"
                else ["split"])
    for shape in applicable_shapes(cfg):
        for v in variants:
            _load(arch, shape.name, "single", v)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_argument_bytes_fit_hbm(arch):
    cfg = get_config(arch)
    budget = 16 * 2 ** 30   # v5e HBM per chip
    for shape in applicable_shapes(cfg):
        rec = _load(arch, shape.name, "single", "base")
        args = rec["memory"]["argument_bytes"]
        assert args < budget, (
            f"{arch}/{shape.name}: {args / 2**30:.1f} GB args > 16 GB HBM")


def test_roofline_terms_computable():
    from repro.launch import roofline as R
    n = 0
    for p in sorted(DRY.glob("*.single.base.json")):
        arch, shape = p.name.split(".")[:2]
        c = R.corrected_cell(DRY, arch, shape, "single")
        assert c is not None
        assert c["t_compute"] > 0 and c["t_memory"] > 0
        assert c["dominant"] in ("compute", "memory", "collective")
        n += 1
    assert n >= 32, f"expected >=32 single-pod cells, found {n}"
