"""Multi-device behaviour on a subprocess mesh (8 fake host devices):
exact integer psum, int8 error-feedback psum, ring collective matmul,
pipeline parallelism, and elastic checkpoint restore across mesh shapes.

Each test runs a child interpreter because the parent's jax is locked to
1 device.
"""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # each test compiles in a child interpreter

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def run_child(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


def test_exact_psum_topology_invariance():
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import exact_accum as EA
from repro.distributed.collectives import exact_psum_tree

x = np.random.default_rng(0).standard_normal((8, 64)).astype(np.float32)
outs = {}
for shape, axes in [((8,), ("data",)), ((4, 2), ("data", "model")),
                    ((2, 4), ("data", "model"))]:
    mesh = jax.make_mesh(shape, axes)
    n = shape[0]

    def f(xl):
        # encode each fixed unit (row), integer-sum locally, integer psum:
        # bitwise identical for ANY replica count / grouping.
        d = EA.encode(xl)                 # (rows_local, 64, L)
        acc = d.sum(0, dtype=jnp.uint32)
        tot = jax.lax.psum(acc, "data")
        return EA.decode(EA.normalize(tot))

    fm = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P())
    with mesh:
        outs[shape] = np.asarray(fm(jnp.asarray(x)))
# 8-way, 4-way, 2-way reductions of the same data: bitwise identical
ref = outs[(8,)]
for k, v in outs.items():
    assert v.tobytes() == ref.tobytes(), f"mismatch for mesh {k}"
# and equal to the single-host exact reduce
want = np.asarray(EA.exact_reduce(jnp.asarray(x), 1))
assert ref.tobytes() == want.tobytes()
print("OK")
""")


def test_int8_ef_psum():
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.collectives import int8_ef_psum

mesh = jax.make_mesh((8,), ("data",))
x = np.random.default_rng(1).standard_normal((8, 128)).astype(np.float32)

def f(xl, ef):
    m, ef = int8_ef_psum(xl[0], ef[0], "data", 8)
    return m[None], ef[None]

fm = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")))
ef = jnp.zeros((8, 128), jnp.float32)
with mesh:
    mean, ef = fm(jnp.asarray(x), ef)
mean = np.asarray(mean)[0]
want = x.mean(0)
err1 = np.abs(mean - want).max()
assert err1 < np.abs(x).max() / 127 * 1.01 + 1e-6, err1
# error feedback: repeating the SAME gradient converges toward exact mean
with mesh:
    for _ in range(8):
        mean, ef = fm(jnp.asarray(x), ef)
# time-average of compressed means approaches the true mean; single-shot
# error already bounded; just assert residual stays bounded
assert np.abs(np.asarray(ef)).max() <= np.abs(x).max() / 127 * 1.01
print("OK")
""")


def test_psum_matmul_ring():
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.collectives import psum_matmul_ring

mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(2)
x = rng.standard_normal((4, 64)).astype(np.float32)
w = rng.standard_normal((64, 32)).astype(np.float32)

def f(xl, wl):
    return psum_matmul_ring(xl, wl, "model", 8)

fm = shard_map(f, mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
               out_specs=P(), check_vma=False)
with mesh:
    out = np.asarray(fm(jnp.asarray(x), jnp.asarray(w)))
np.testing.assert_allclose(out, x @ w, rtol=2e-4, atol=2e-4)
print("OK")
""")


def test_pipeline_parallel_forward():
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import run_pipelined

mesh = jax.make_mesh((4,), ("stage",))
rng = np.random.default_rng(3)
S, D = 4, 16
Ws = rng.standard_normal((S, D, D)).astype(np.float32) * 0.3
x = rng.standard_normal((8, D)).astype(np.float32)

def stage_fn(w, h):
    return jnp.tanh(h @ w)

out = run_pipelined(mesh, stage_fn, jnp.asarray(Ws), jnp.asarray(x),
                    microbatches=4, axis_name="stage")
ref = x
for s in range(S):
    ref = np.tanh(ref @ Ws[s])
np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)
print("OK")
""")


def test_elastic_checkpoint_restore_across_meshes():
    run_child("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as C

tmp = tempfile.mkdtemp()
mesh8 = jax.make_mesh((8,), ("data",))
x = jnp.arange(8 * 32, dtype=jnp.float32).reshape(8, 32)
xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
C.save(tmp, 1, {"w": xs})

mesh4 = jax.make_mesh((2, 4), ("data", "model"))
sh = {"w": NamedSharding(mesh4, P("model", None))}
back, _ = C.restore(f"{tmp}/step_000000001", {"w": x}, shardings=sh)
np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(x))
assert back["w"].sharding.spec == P("model", None)
print("OK")
""")


def test_reduced_dryrun_on_small_mesh():
    """End-to-end mini dry-run: reduced arch, sharded train_step lower +
    compile + cost analysis on an 8-device mesh."""
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.distributed import sharding as sh
from repro.train import optimizer

mesh = jax.make_mesh((2, 4), ("data", "model"))
sh.enable_fsdp(mesh)
cfg = get_config("smollm_135m", reduced=True)
model = build_model(cfg)
params_s = jax.eval_shape(model.init, jax.random.key(0))
pspecs = sh.param_pspecs(params_s, mesh)
p_shard = sh.to_shardings(pspecs, mesh)
batch_s = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
           "targets": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
b_shard = sh.to_shardings(sh.batch_pspecs(batch_s, mesh), mesh)
opt_s = jax.eval_shape(optimizer.init, params_s)
o_shard = sh.to_shardings({"m": pspecs, "v": pspecs, "step": P()}, mesh)
ocfg = optimizer.OptConfig()

def train_step(params, opt, batch):
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    return optimizer.update(ocfg, grads, opt, params)

with mesh:
    co = jax.jit(train_step, in_shardings=(p_shard, o_shard, b_shard),
                 donate_argnums=(0, 1)).lower(params_s, opt_s, batch_s).compile()
from repro.compat import cost_analysis_dict
c = cost_analysis_dict(co)
assert c["flops"] > 0
print("OK", c["flops"])
""")
