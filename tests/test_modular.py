"""Montgomery arithmetic / modexp / RSA / pi vs Python-int oracles."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import limbs as L
from repro.core import modular as M
from repro.core import rsa as R

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("nbits", [64, 256, 512])
def test_mont_mul_random(nbits):
    n = None
    while n is None or n % 2 == 0:
        n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 8, nbits)]
    ys = [v % n for v in L.random_bigints(RNG, 8, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    b = jnp.asarray(np.stack([L.int_to_limbs(y, ctx.m, 16) for y in ys]))
    out = np.asarray(M.mod_mul(a, b, ctx))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(out[i], 16) == (x * y) % n


@pytest.mark.parametrize("nbits,ebits", [(64, 16), (256, 64)])
def test_mod_exp_random(nbits, ebits):
    n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = M.mont_setup(n, nbits)
    e = L.random_bigints(RNG, 1, ebits)[0] | 1
    xs = [v % n for v in L.random_bigints(RNG, 4, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    out = np.asarray(M.mod_exp(a, jnp.asarray(M.exp_bits_msb(e)), ctx))
    for i, x in enumerate(xs):
        assert L.limbs_to_int(out[i], 16) == pow(x, e, n)


def test_rsa_sign_verify_roundtrip():
    key = R.generate_key(bits=256, seed=5)
    msgs = [R.digest_int(f"msg{i}".encode(), key.bits) for i in range(4)]
    md = R.messages_to_digits(msgs, key)
    sigs = R.sign(md, key)
    back = np.asarray(R.verify(sigs, key))
    for i, m in enumerate(msgs):
        assert L.limbs_to_int(back[i], 16) == m % key.n
    # oracle: python pow
    s0 = L.limbs_to_int(np.asarray(sigs)[0], 16)
    assert s0 == pow(msgs[0] % key.n, key.d, key.n)


def test_pi_digits():
    from repro.core import pi as P
    got = P.pi_digits(50)
    want = P.pi_reference(50)
    assert got[:40] == want[:40], f"{got} vs {want}"
    assert want.startswith("3.14159265358979")


def test_gcd_batched():
    import math
    from repro.core import gcd as G
    rng = np.random.default_rng(21)
    nbits = 256
    nd = nbits // 16
    xs = L.random_bigints(rng, 8, nbits)
    ys = L.random_bigints(rng, 8, nbits)
    # plant common factors in half the lanes
    for i in range(0, 8, 2):
        g = L.random_bigints(rng, 1, 64)[0] | 1
        xs[i] = (xs[i] // g) * g if xs[i] >= g else g
        ys[i] = (ys[i] // g) * g if ys[i] >= g else g
    u = jnp.asarray(np.stack([L.int_to_limbs(x, nd, 16) for x in xs]))
    v = jnp.asarray(np.stack([L.int_to_limbs(y, nd, 16) for y in ys]))
    out = np.asarray(jax.jit(G.gcd)(u, v))
    for i in range(8):
        assert L.limbs_to_int(out[i], 16) == math.gcd(xs[i], ys[i]), i


def test_gcd_edge_cases():
    import math
    from repro.core import gcd as G
    nd = 8
    cases = [(12, 18), (1, 1), (0, 5), (7, 0), (2**96, 2**64), (17, 17)]
    u = jnp.asarray(np.stack([L.int_to_limbs(a, nd, 16) for a, _ in cases]))
    v = jnp.asarray(np.stack([L.int_to_limbs(b, nd, 16) for _, b in cases]))
    out = np.asarray(G.gcd(u, v))
    for i, (a, b) in enumerate(cases):
        assert L.limbs_to_int(out[i], 16) == math.gcd(a, b), (i, a, b)
