"""Montgomery arithmetic / modexp / RSA / pi vs Python-int oracles."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import limbs as L
from repro.core import modular as M
from repro.core import rsa as R

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("nbits", [64, 256, 512])
def test_mont_mul_random(nbits):
    n = None
    while n is None or n % 2 == 0:
        n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 8, nbits)]
    ys = [v % n for v in L.random_bigints(RNG, 8, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    b = jnp.asarray(np.stack([L.int_to_limbs(y, ctx.m, 16) for y in ys]))
    out = np.asarray(M.mod_mul(a, b, ctx))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(out[i], 16) == (x * y) % n


@pytest.mark.parametrize("nbits,ebits", [(64, 16), (256, 64)])
def test_mod_exp_random(nbits, ebits):
    n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = M.mont_setup(n, nbits)
    e = L.random_bigints(RNG, 1, ebits)[0] | 1
    xs = [v % n for v in L.random_bigints(RNG, 4, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    out = np.asarray(M.mod_exp(a, jnp.asarray(M.exp_bits_msb(e)), ctx))
    for i, x in enumerate(xs):
        assert L.limbs_to_int(out[i], 16) == pow(x, e, n)


BARRETT_WIDTHS = [256, 512,
                  pytest.param(1024, marks=pytest.mark.slow),
                  pytest.param(2048, marks=pytest.mark.slow)]


@pytest.mark.parametrize("nbits", BARRETT_WIDTHS)
@pytest.mark.parametrize("parity", ["odd", "even"])
def test_barrett_mod_mul_vs_python_int(nbits, parity):
    n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1))
    n = (n | 1) if parity == "odd" else (n & ~1)
    ctx = M.barrett_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 6, nbits)]
    ys = [v % n for v in L.random_bigints(RNG, 6, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    b = jnp.asarray(np.stack([L.int_to_limbs(y, ctx.m, 16) for y in ys]))
    out = np.asarray(jax.jit(
        lambda a, b: M.barrett_mod_mul(a, b, ctx))(a, b))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(out[i], 16) == (x * y) % n, i


def test_barrett_modexp_matches_montgomery():
    """Same odd modulus, same exponent: the Barrett ladder must agree
    with both Montgomery formulations and the Python oracle."""
    nbits, ebits = 256, 24
    n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1)) | 1
    ctx = M.mont_setup(n, nbits)
    e = L.random_bigints(RNG, 1, ebits)[0] | 1
    xs = [v % n for v in L.random_bigints(RNG, 4, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    eb = jnp.asarray(M.exp_bits_msb(e))
    got_b = np.asarray(M.mod_exp(a, eb, ctx, backend="barrett"))
    got_m = np.asarray(M.mod_exp(a, eb, ctx, backend="jnp"))
    np.testing.assert_array_equal(got_b, got_m)
    for i, x in enumerate(xs):
        assert L.limbs_to_int(got_b[i], 16) == pow(x, e, n), i


def test_even_modulus_auto_routes_to_barrett():
    """mod_setup gives a BarrettCtx for even n; Montgomery-backend
    requests on it silently (and correctly) take the Barrett path."""
    nbits, ebits = 128, 16
    n = (L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1))) & ~1
    ctx = M.mod_setup(n)
    assert isinstance(ctx, M.BarrettCtx)
    e = L.random_bigints(RNG, 1, ebits)[0] | 1
    xs = [v % n for v in L.random_bigints(RNG, 4, nbits)]
    a = jnp.asarray(np.stack([L.int_to_limbs(x, ctx.m, 16) for x in xs]))
    eb = jnp.asarray(M.exp_bits_msb(e))
    for be in ("jnp", "pallas", "barrett"):
        got = np.asarray(M.mod_exp(a, eb, ctx, backend=be))
        for i, x in enumerate(xs):
            assert L.limbs_to_int(got[i], 16) == pow(x, e, n), (be, i)


def test_barrett_setup_rejects_overdeclared_width():
    """Padding nbits past the modulus breaks the trial-quotient bound;
    the error must name the fix, not crash deep in limb packing."""
    with pytest.raises(ValueError, match="nbits"):
        M.barrett_setup(1000003, nbits=64)
    assert M.barrett_setup(1000003, nbits=32).m == 2   # exact width: fine


def test_mont_setup_rejects_even_modulus():
    with pytest.raises(ValueError, match="Barrett"):
        M.mont_setup(1 << 64)
    with pytest.raises(ValueError, match="mod_mul"):
        key_n = L.random_bigints(RNG, 1, 64)[0] | (1 << 63) | 1
        ctx = M.mont_setup(key_n)
        a = jnp.zeros((1, ctx.m), jnp.uint32)
        M.mont_mul(a, a, ctx, backend="barrett")


def test_rsa_crt_decrypt_matches_full():
    from repro.core import rsa as R2
    key = R2.generate_key(bits=192, seed=9)
    assert key.p * key.q == key.n
    msgs = [R2.digest_int(f"c{i}".encode(), key.bits) for i in range(3)]
    md = R2.messages_to_digits(msgs, key)
    full = np.asarray(R2.sign(md, key))            # m^d mod n
    crt = np.asarray(jax.jit(lambda x: R2.decrypt_crt(x, key))(md))
    np.testing.assert_array_equal(crt, full)
    for i, m in enumerate(msgs):
        assert L.limbs_to_int(crt[i], 16) == pow(m % key.n, key.d, key.n), i


def test_rsa_sign_verify_roundtrip():
    key = R.generate_key(bits=256, seed=5)
    msgs = [R.digest_int(f"msg{i}".encode(), key.bits) for i in range(4)]
    md = R.messages_to_digits(msgs, key)
    sigs = R.sign(md, key)
    back = np.asarray(R.verify(sigs, key))
    for i, m in enumerate(msgs):
        assert L.limbs_to_int(back[i], 16) == m % key.n
    # oracle: python pow
    s0 = L.limbs_to_int(np.asarray(sigs)[0], 16)
    assert s0 == pow(msgs[0] % key.n, key.d, key.n)


def test_pi_digits():
    from repro.core import pi as P
    got = P.pi_digits(50)
    want = P.pi_reference(50)
    assert got[:40] == want[:40], f"{got} vs {want}"
    assert want.startswith("3.14159265358979")


def test_gcd_batched():
    import math
    from repro.core import gcd as G
    rng = np.random.default_rng(21)
    nbits = 256
    nd = nbits // 16
    xs = L.random_bigints(rng, 8, nbits)
    ys = L.random_bigints(rng, 8, nbits)
    # plant common factors in half the lanes
    for i in range(0, 8, 2):
        g = L.random_bigints(rng, 1, 64)[0] | 1
        xs[i] = (xs[i] // g) * g if xs[i] >= g else g
        ys[i] = (ys[i] // g) * g if ys[i] >= g else g
    u = jnp.asarray(np.stack([L.int_to_limbs(x, nd, 16) for x in xs]))
    v = jnp.asarray(np.stack([L.int_to_limbs(y, nd, 16) for y in ys]))
    out = np.asarray(jax.jit(G.gcd)(u, v))
    for i in range(8):
        assert L.limbs_to_int(out[i], 16) == math.gcd(xs[i], ys[i]), i


def test_gcd_edge_cases():
    import math
    from repro.core import gcd as G
    nd = 8
    cases = [(12, 18), (1, 1), (0, 5), (7, 0), (2**96, 2**64), (17, 17)]
    u = jnp.asarray(np.stack([L.int_to_limbs(a, nd, 16) for a, _ in cases]))
    v = jnp.asarray(np.stack([L.int_to_limbs(b, nd, 16) for _, b in cases]))
    out = np.asarray(G.gcd(u, v))
    for i, (a, b) in enumerate(cases):
        assert L.limbs_to_int(out[i], 16) == math.gcd(a, b), (i, a, b)
