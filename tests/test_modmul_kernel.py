"""Fused Montgomery kernel (interpret mode on CPU) vs the Python-int
oracle, plus backend-dispatch agreement and RSA round-trips through the
pallas path.  The oracle (kernels/dot_modmul/ref.py) is independent of
all jnp code, so a kernel bug and a core/modular.py bug cannot cancel.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import limbs as L
from repro.core import modular as M
from repro.core import rsa as R
from repro.kernels.dot_modmul import ops, ref

RNG = np.random.default_rng(13)


def _odd_modulus(nbits):
    return L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1)) | 1


def _digit_batch(ints, m):
    return np.stack([L.int_to_limbs(v, m, 16) for v in ints])


@pytest.mark.parametrize("nbits", [256, 512, 1024])
def test_mont_mul_kernel_vs_oracle(nbits):
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 9, nbits)]
    ys = [v % n for v in L.random_bigints(RNG, 9, nbits)]
    out = np.asarray(ops.dot_mont_mul(
        _digit_batch(xs, ctx.m), _digit_batch(ys, ctx.m), ctx))
    want = ref.mont_mul_ref(_digit_batch(xs, ctx.m),
                            _digit_batch(ys, ctx.m), n)
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("batch", [1, 7, 300])
def test_mont_mul_kernel_padding_tiles(batch):
    nbits = 128
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, batch, nbits)]
    ys = [v % n for v in L.random_bigints(RNG, batch, nbits)]
    out = np.asarray(ops.dot_mont_mul(
        _digit_batch(xs, ctx.m), _digit_batch(ys, ctx.m), ctx))
    want = ref.mont_mul_ref(_digit_batch(xs, ctx.m),
                            _digit_batch(ys, ctx.m), n)
    np.testing.assert_array_equal(out, want)


def test_mont_mul_kernel_edge_operands():
    """0, 1, n-1 exercise the conditional-subtract boundary."""
    nbits = 192
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [0, 1, n - 1, n - 1, 1, n // 2]
    ys = [0, 1, n - 1, 1, n - 1, 2]
    out = np.asarray(ops.dot_mont_mul(
        _digit_batch(xs, ctx.m), _digit_batch(ys, ctx.m), ctx))
    want = ref.mont_mul_ref(_digit_batch(xs, ctx.m),
                            _digit_batch(ys, ctx.m), n)
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("nbits,ebits", [(256, 64), (512, 32), (1024, 16)])
def test_mod_exp_kernel_vs_oracle(nbits, ebits):
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    e = L.random_bigints(RNG, 1, ebits)[0] | 1
    xs = [v % n for v in L.random_bigints(RNG, 4, nbits)]
    out = np.asarray(ops.dot_mod_exp(
        _digit_batch(xs, ctx.m), jnp.asarray(M.exp_bits_msb(e)), ctx))
    want = ref.mod_exp_ref(_digit_batch(xs, ctx.m), e, n)
    np.testing.assert_array_equal(out, want)


def test_mod_exp_kernel_per_lane_exponents():
    nbits = 128
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 6, nbits)]
    es = [v | 1 for v in L.random_bigints(RNG, 6, 32)]
    eb = jnp.asarray(np.stack([M.exp_bits_msb(e, 32) for e in es]))
    out = np.asarray(ops.dot_mod_exp(_digit_batch(xs, ctx.m), eb, ctx))
    for i, (x, e) in enumerate(zip(xs, es)):
        assert L.limbs_to_int(out[i], 16) == pow(x, e, n), i


def test_backend_dispatch_agreement():
    """reference / jnp / pallas produce identical digits via one API."""
    nbits = 128
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 5, nbits)]
    ys = [v % n for v in L.random_bigints(RNG, 5, nbits)]
    a = jnp.asarray(_digit_batch(xs, ctx.m))
    b = jnp.asarray(_digit_batch(ys, ctx.m))
    outs = {be: np.asarray(M.mod_mul(a, b, ctx, backend=be))
            for be in M.BACKENDS}
    for be in M.BACKENDS:
        np.testing.assert_array_equal(outs[be], outs["reference"], be)
    e = 65537
    eb = jnp.asarray(M.exp_bits_msb(e))
    outs = {be: np.asarray(M.mod_exp(a, eb, ctx, backend=be))
            for be in M.BACKENDS}
    for be in M.BACKENDS:
        np.testing.assert_array_equal(outs[be], outs["reference"], be)


def test_default_backend_setter():
    assert M.get_default_backend() == "jnp"
    with pytest.raises(ValueError):
        M.set_default_backend("nope")
    M.set_default_backend("pallas")
    try:
        assert M.get_default_backend() == "pallas"
    finally:
        M.set_default_backend("jnp")


def test_explicit_backend_ignores_default():
    """backend="jnp" must not leak through to the module default (the
    internal to_mont/from_mont calls once did, crashing under jit when
    the default was "reference")."""
    import jax
    nbits = 128
    n = _odd_modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 3, nbits)]
    a = jnp.asarray(_digit_batch(xs, ctx.m))
    eb = jnp.asarray(M.exp_bits_msb(65537))
    M.set_default_backend("reference")
    try:
        out = np.asarray(jax.jit(
            lambda x: M.mod_exp(x, eb, ctx, backend="jnp"))(a))
    finally:
        M.set_default_backend("jnp")
    for i, x in enumerate(xs):
        assert L.limbs_to_int(out[i], 16) == pow(x, 65537, n), i


def test_rsa_sign_verify_roundtrip_pallas():
    """Full modexp round-trip through core/rsa.py on the pallas backend."""
    key = R.generate_key(bits=256, seed=7)
    msgs = [R.digest_int(f"pmsg{i}".encode(), key.bits) for i in range(4)]
    md = R.messages_to_digits(msgs, key)
    sigs = R.sign(md, key, backend="pallas")
    back = np.asarray(R.verify(sigs, key, backend="pallas"))
    for i, m in enumerate(msgs):
        assert L.limbs_to_int(back[i], 16) == m % key.n
    # oracle: python pow, and cross-backend identical signatures
    s0 = L.limbs_to_int(np.asarray(sigs)[0], 16)
    assert s0 == pow(msgs[0] % key.n, key.d, key.n)
    sigs_jnp = np.asarray(R.sign(md, key, backend="jnp"))
    np.testing.assert_array_equal(np.asarray(sigs), sigs_jnp)
