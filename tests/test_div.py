"""Division subsystem vs Python-int ground truth: Newton reciprocal,
divmod (kernel + reciprocal paths), constant-divisor division, base
conversion, dispatch coverage, and (with hypothesis) the exactness
invariant q*b + r == a, 0 <= r < b.

Kernel oracle tests run the Pallas kernel in interpret mode on CPU;
widths at/above 256 bits are slow-marked (the unrolled Knuth-D step
count makes interpret-mode tracing expensive), matching the CI
fast-subset policy.  Hypothesis strategies use FIXED array widths and
random values so each suite compiles a handful of traces, not one per
example.
"""
import numpy as np
import pytest
import jax.numpy as jnp

import repro.core.div as DV
from repro.core import limbs as L
from repro.kernels.dot_div import ops as div_ops
from repro.kernels.dot_div import ref as div_ref

RNG = np.random.default_rng(17)


def _digits(ints, nd, bits=16):
    return np.stack([L.int_to_limbs(v, nd, bits) for v in ints])


def _check_divmod(q, r, xs, ys, bits):
    q, r = np.asarray(q), np.asarray(r)
    for i, (x, y) in enumerate(zip(xs, ys)):
        qi = L.limbs_to_int(q[i], bits)
        ri = L.limbs_to_int(r[i], bits)
        assert qi == x // y and ri == x % y, (i, x, y, qi, ri)


# ---------------------------------------------------------------------------
# Newton reciprocal: never overestimates, undershoots by at most a few.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", [64, 256, 512])
def test_recip_digits_bounds(nbits):
    nd = nbits // 16
    bs = [x | (1 << (nbits - 1)) for x in L.random_bigints(RNG, 8, nbits)]
    v = np.asarray(DV.recip_digits(jnp.asarray(_digits(bs, nd))))
    for i, b in enumerate(bs):
        err = (1 << (32 * nd)) // b - L.limbs_to_int(v[i], 16)
        assert 0 <= err <= 4, (nbits, i, err)


def test_recip_limbs32_bounds():
    nbits, m = 256, 8
    bs = [max(1, b) for b in L.random_bigints(RNG, 8, nbits)]
    v, s = DV.recip_limbs32(jnp.asarray(L.ints_to_batch(bs, m)))
    v, s = np.asarray(v), np.asarray(s)
    for i, b in enumerate(bs):
        b_norm = b << int(s[i])
        assert 1 << (32 * m - 1) <= b_norm < 1 << (32 * m)
        err = (1 << (64 * m)) // b_norm - L.limbs_to_int(v[i], 32)
        assert 0 <= err <= 4, (i, err)


# ---------------------------------------------------------------------------
# Pallas Knuth-D kernel vs the independent Python-int oracle.
# ---------------------------------------------------------------------------

KERNEL_WIDTHS = [64, 128,
                 pytest.param(256, marks=pytest.mark.slow),
                 pytest.param(512, marks=pytest.mark.slow)]


@pytest.mark.parametrize("nbits", KERNEL_WIDTHS)
def test_div_kernel_vs_python_int(nbits):
    nd = nbits // 16
    xs = L.random_bigints(RNG, 7, nbits)
    ys = [max(1, y) for y in L.random_bigints(RNG, 7, nbits - 9)]
    a, b = _digits(xs, nd), _digits(ys, nd)
    q, r = div_ops.dot_divmod_digits(a, b)
    qr, rr = div_ref.divmod_ref(a, b)
    np.testing.assert_array_equal(np.asarray(q), qr)
    np.testing.assert_array_equal(np.asarray(r), rr)


def test_div_kernel_pathological_and_padding():
    """Odd batch exercises tile padding; pathological pairs exercise the
    trial-quotient add-back corrections."""
    nbits, nd = 128, 8
    pairs = [(x, max(1, y)) for x, y in L.pathological_pairs(nbits, bits=16)]
    pairs += [(12345, 1), (1 << 127, 1 << 90), (5, 7), (0, 3),
              ((1 << 128) - 1, (1 << 64) + 1)]
    q, r = div_ops.dot_divmod_digits(
        _digits([p[0] for p in pairs], nd), _digits([p[1] for p in pairs], nd))
    _check_divmod(q, r, [p[0] for p in pairs], [p[1] for p in pairs], 16)


# ---------------------------------------------------------------------------
# divmod_limbs32 vs Python ints across the acceptance grid.
# ---------------------------------------------------------------------------

# (nbits, divmod method, batch, forced mul backend, marks).  The forced
# "dot" rows keep the 2048/4096-bit oracle runs tractable on CPU: the
# interpret-mode kernels and the unrolled jnp Karatsuba both take
# minutes of XLA compile at those multiply widths, while the VnC
# composition compiles in seconds and its quadratic runtime is
# irrelevant at batch 64 (the mul backends are oracle-tested
# independently in test_mul_pipeline).
DIVMOD_GRID = [
    (512, "recip", 64, None, None),
    (128, "auto", 8, None, None),            # auto -> schoolbook kernel
    (512, "auto", 8, None, pytest.mark.slow),   # kernel at the boundary
    (1024, "recip", 64, None, pytest.mark.slow),
    (2048, "recip", 64, "dot", pytest.mark.slow),
    (4096, "recip", 64, "dot", pytest.mark.slow),
]


@pytest.mark.parametrize(
    "nbits,method,batch,mul_backend",
    [pytest.param(n, me, ba, mb, marks=mk) if mk else (n, me, ba, mb)
     for n, me, ba, mb, mk in DIVMOD_GRID])
def test_divmod_limbs32_vs_python_int(nbits, method, batch, mul_backend,
                                      monkeypatch):
    if mul_backend:
        monkeypatch.setenv("REPRO_MUL_BACKEND", mul_backend)
    m = nbits // 32
    xs = L.random_bigints(RNG, batch, nbits)
    ys = [max(1, y) for y in L.random_bigints(RNG, batch, nbits - 11)]
    q, r = DV.divmod_jit(jnp.asarray(L.ints_to_batch(xs, m)),
                         jnp.asarray(L.ints_to_batch(ys, m)), method)
    _check_divmod(q, r, xs, ys, 32)


def test_divmod_wide_dividend_narrow_divisor():
    """The reciprocal must carry QUOTIENT-width precision: a divisor-
    width reciprocal leaves a ~D**(na-nb) quotient error for shapes like
    512-bit / 64-bit, which the +1-per-trip correction loop can never
    close (regression test for exactly that hang)."""
    ma, mb = 16, 2                           # 512-bit a, 64-bit b
    xs = L.random_bigints(RNG, 8, 32 * ma)
    ys = [max(1, y) for y in L.random_bigints(RNG, 8, 29)]
    q, r = DV.divmod_limbs32(jnp.asarray(L.ints_to_batch(xs, ma)),
                             jnp.asarray(L.ints_to_batch(ys, mb)),
                             method="recip")
    q, r = np.asarray(q), np.asarray(r)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(q[i], 32) == x // y, i
        assert L.limbs_to_int(r[i], 32) == x % y, i


def test_divmod_leading_batch_dims():
    nbits, m = 512, 16
    xs = L.random_bigints(RNG, 6, nbits)
    ys = [max(1, y) for y in L.random_bigints(RNG, 6, 200)]
    a = L.ints_to_batch(xs, m).reshape(2, 3, m)
    b = L.ints_to_batch(ys, m).reshape(2, 3, m)
    q, r = DV.divmod_limbs32(a, b, method="recip")
    assert q.shape == (2, 3, m) and r.shape == (2, 3, m)
    _check_divmod(np.asarray(q).reshape(6, m), np.asarray(r).reshape(6, m),
                  xs, ys, 32)


# ---------------------------------------------------------------------------
# Dispatch: select_div_method branches + env override.
# ---------------------------------------------------------------------------

def test_select_div_method_branches():
    from repro.configs.dot_bignum import DIV_DISPATCH as cfg
    from repro.configs.dot_bignum import MUL_DISPATCH
    B = 64                        # batch large enough to amortize a launch
    assert DV.select_div_method(256, 256, batch=B) == "schoolbook"
    assert DV.select_div_method(cfg.schoolbook_max_bits, 64,
                                batch=B) == "schoolbook"
    assert DV.select_div_method(cfg.schoolbook_max_bits + 32, 64,
                                batch=B) == "recip"
    assert DV.select_div_method(8192, 4096, batch=B) == "recip"
    # tiny batches cannot amortize the kernel launch: reciprocal path
    small = MUL_DISPATCH.kernel_min_batch - 1
    assert DV.select_div_method(256, 256, batch=small) == "recip"


def test_select_div_method_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_DIV_BACKEND", "recip")
    assert DV.select_div_method(256, 256) == "recip"
    monkeypatch.setenv("REPRO_DIV_BACKEND", "bogus")
    with pytest.raises(ValueError):
        DV.select_div_method(256, 256)


# ---------------------------------------------------------------------------
# Constant-divisor division + on-device base conversion.
# ---------------------------------------------------------------------------

def test_divmod_const_exact():
    nd = 16                                    # 256-bit values
    xs = L.random_bigints(RNG, 6, 16 * nd)
    x = jnp.asarray(_digits(xs, nd))
    for c in (1, 7, 10 ** 9, 10 ** 40, 2 ** 100, (1 << 255) - 1):
        q, r = DV.divmod_const(x, c)
        for i, v in enumerate(xs):
            assert L.limbs_to_int(np.asarray(q)[i], 16) == v // c, (c, i)
            assert L.limbs_to_int(np.asarray(r)[i], 16) == v % c, (c, i)


def test_to_decimal_digits():
    n_dec = 73
    nd = DV._dec_width(n_dec, 16)
    xs = [v % 10 ** n_dec for v in L.random_bigints(RNG, 5, 16 * nd)]
    xs += [0, 10 ** n_dec - 1, 1]
    dec = np.asarray(DV.to_decimal_digits(jnp.asarray(_digits(xs, nd)), n_dec))
    assert dec.shape == (len(xs), n_dec)
    for i, v in enumerate(xs):
        assert "".join(map(str, dec[i])) == str(v).zfill(n_dec), (i, v)


def test_to_decimal_limbs32():
    n_dec = 30
    m = 4
    xs = [v % 10 ** n_dec for v in L.random_bigints(RNG, 4, 32 * m)]
    dec = np.asarray(DV.to_decimal_limbs32(
        jnp.asarray(L.ints_to_batch(xs, m)), n_dec))
    for i, v in enumerate(xs):
        assert "".join(map(str, dec[i])) == str(v).zfill(n_dec), (i, v)


def test_div_small_matches_python():
    nd = 20
    xs = L.random_bigints(RNG, 6, 16 * nd)
    x = jnp.asarray(_digits(xs, nd))
    for s in (1, 3, 239 * 239, 65535):
        q = np.asarray(DV.div_small(x, s))
        for i, v in enumerate(xs):
            assert L.limbs_to_int(q[i], 16) == v // s, (s, i)


# ---------------------------------------------------------------------------
# Shift/compare helpers (the normalization machinery).
# ---------------------------------------------------------------------------

def test_bit_length_and_shifts_roundtrip():
    nd = 8
    xs = [0, 1, 5, 1 << 64, (1 << 128) - 1] + L.random_bigints(RNG, 3, 100)
    x = jnp.asarray(_digits(xs, nd))
    bl = np.asarray(DV.bit_length_digits(x))
    assert [int(v) for v in bl] == [v.bit_length() for v in xs]
    s = jnp.asarray(np.asarray(
        [nd * 16 - v.bit_length() if v else 0 for v in xs], np.uint32))
    up = DV.shift_left_bits(x, s)
    down = np.asarray(DV.shift_right_bits(up, s))
    for i, v in enumerate(xs):
        assert L.limbs_to_int(np.asarray(up)[i], 16) == v << int(s[i]), i
        assert L.limbs_to_int(down[i], 16) == v, i


# ---------------------------------------------------------------------------
# Hypothesis: the divmod invariant across digit_bits in {8, 12, 16}.
# Fixed widths per digit_bits (one trace each), random values.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:          # pragma: no cover - dev extra missing
    HAVE_HYP = False

if HAVE_HYP:
    import functools

    import jax

    SET = settings(max_examples=25, deadline=None)
    NA, NB = 12, 12                      # fixed digit widths per trace

    @functools.lru_cache(maxsize=8)
    def _divmod_compiled(digit_bits):
        return jax.jit(functools.partial(
            DV.divmod_digits, digit_bits=digit_bits, method="recip"))

    def _invariant(x, y, digit_bits):
        a = jnp.asarray(L.int_to_limbs(x, NA, digit_bits))[None]
        b = jnp.asarray(L.int_to_limbs(y, NB, digit_bits))[None]
        q, r = _divmod_compiled(digit_bits)(a, b)
        qi = L.limbs_to_int(np.asarray(q)[0], digit_bits)
        ri = L.limbs_to_int(np.asarray(r)[0], digit_bits)
        assert qi * y + ri == x, (x, y, qi, ri)
        assert 0 <= ri < y, (x, y, ri)
        assert qi == x // y and ri == x % y

    @given(st.data())
    @SET
    def test_divmod_invariant_16(data):
        x = data.draw(st.integers(0, (1 << (16 * NA)) - 1))
        y = data.draw(st.integers(1, (1 << (16 * NB)) - 1))
        _invariant(x, y, 16)

    @given(st.data())
    @SET
    def test_divmod_invariant_12(data):
        x = data.draw(st.integers(0, (1 << (12 * NA)) - 1))
        y = data.draw(st.integers(1, (1 << (12 * NB)) - 1))
        _invariant(x, y, 12)

    @given(st.data())
    @SET
    def test_divmod_invariant_8(data):
        x = data.draw(st.integers(0, (1 << (8 * NA)) - 1))
        y = data.draw(st.integers(1, (1 << (8 * NB)) - 1))
        _invariant(x, y, 8)

    @given(st.data())
    @SET
    def test_divmod_invariant_asymmetric_widths(data):
        """Wide dividend over narrow divisor (the regime that needs
        quotient-width reciprocal precision) and the reverse."""
        a = jnp.asarray(L.int_to_limbs(
            data.draw(st.integers(0, (1 << (16 * 20)) - 1)), 20, 16))[None]
        b = jnp.asarray(L.int_to_limbs(
            data.draw(st.integers(1, (1 << (16 * 3)) - 1)), 3, 16))[None]
        q, r = _divmod_compiled(16)(a, b)
        x = L.limbs_to_int(np.asarray(a)[0], 16)
        y = L.limbs_to_int(np.asarray(b)[0], 16)
        assert L.limbs_to_int(np.asarray(q)[0], 16) == x // y
        assert L.limbs_to_int(np.asarray(r)[0], 16) == x % y

    @given(st.data())
    @SET
    def test_divmod_special_divisors(data):
        """b == 1, a < b, and power-of-two divisors."""
        digit_bits = data.draw(st.sampled_from([8, 12, 16]))
        x = data.draw(st.integers(0, (1 << (digit_bits * NA)) - 1))
        kind = data.draw(st.sampled_from(["one", "a_lt_b", "pow2"]))
        if kind == "one":
            y = 1
        elif kind == "a_lt_b":
            y = data.draw(st.integers(1, (1 << (digit_bits * NB)) - 1))
            x = data.draw(st.integers(0, y - 1))
        else:
            y = 1 << data.draw(st.integers(0, digit_bits * NB - 1))
        _invariant(x, y, digit_bits)
else:                        # keep collection green without the dev extra
    def test_divmod_invariant_16():
        pytest.skip("hypothesis not installed")
