"""NTT/CRT huge-operand multiply subsystem (kernels/ntt_mul) vs Python-int
ground truth, plus the layers under it: the uint32-only wide-multiply /
Montgomery primitives, the twiddle tables, the forward transform against
an O(N^2) DFT oracle, Garner CRT recombination, and the core/mul.py
dispatch tier that routes huge operands here.

Oracle widths follow the CI fast-subset policy: 4096/8192-bit oracles run
on PRs, the >= 16384-bit grid (where a single interpret-mode launch still
takes seconds) is slow-marked.  Both CRT prime-set sizes (2 and 3) are
exercised at every tested width, at batch 1 and batch >= 8.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core.mul as M
from repro.core import limbs as L
from repro.kernels.ntt_mul import kernel as NK
from repro.kernels.ntt_mul import ops as NO
from repro.kernels.ntt_mul import ref as NREF

RNG = np.random.default_rng(11)
R = 1 << 32


# ---------------------------------------------------------------------------
# uint32-only arithmetic primitives.
# ---------------------------------------------------------------------------

def test_mul32_wide_exact():
    xs = RNG.integers(0, 1 << 32, 256, dtype=np.int64).astype(np.uint32)
    ys = RNG.integers(0, 1 << 32, 256, dtype=np.int64).astype(np.uint32)
    # adversarial corners: the cross-sum and low-word carries must fire
    edge = np.array([0, 1, 0xFFFF, 0x10000, 0xFFFFFFFF, 0xFFFF0000,
                     0x0000FFFF, 0x80000000], np.uint32)
    xs = np.concatenate([xs, edge, edge])
    ys = np.concatenate([ys, edge, edge[::-1]])
    hi, lo = NK.mul32_wide(jnp.asarray(xs), jnp.asarray(ys))
    got = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) \
        | np.asarray(lo).astype(np.uint64)
    want = xs.astype(np.uint64) * ys.astype(np.uint64)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("p", NK.PRIMES)
def test_mont_mul_matches_python(p):
    pinv = (-pow(p, -1, R)) % R
    xs = RNG.integers(0, p, 512, dtype=np.int64)
    ys = RNG.integers(0, p, 512, dtype=np.int64)
    # corners: 0, 1, p-1 against each other and the random draw
    edge = np.array([0, 1, p - 1, p // 2, p // 2 + 1], np.int64)
    xs = np.concatenate([xs, edge, edge])
    ys = np.concatenate([ys, edge, edge[::-1]])
    got = np.asarray(NK.mont_mul(jnp.asarray(xs.astype(np.uint32)),
                                 jnp.asarray(ys.astype(np.uint32)), p, pinv))
    rinv = pow(R, -1, p)
    for x, y, g in zip(xs, ys, got):
        assert int(g) == int(x) * int(y) * rinv % p


@pytest.mark.parametrize("p", NK.PRIMES)
def test_mod_add_sub(p):
    xs = RNG.integers(0, p, 256, dtype=np.int64)
    ys = RNG.integers(0, p, 256, dtype=np.int64)
    a = jnp.asarray(xs.astype(np.uint32))
    b = jnp.asarray(ys.astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(NK.add_mod(a, b, p)), (xs + ys) % p)
    np.testing.assert_array_equal(
        np.asarray(NK.sub_mod(a, b, p)), (xs - ys) % p)


# ---------------------------------------------------------------------------
# Twiddle tables + the transform itself (vs an O(N^2) Python-int DFT).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", NK.PRIMES)
def test_twiddle_tables_are_root_powers(p):
    n = 64
    wf, wi = NO.twiddle_tables(p, n)
    rinv = pow(R, -1, p)
    w = pow(NK.GENERATOR, (p - 1) // n, p)
    assert pow(w, n, p) == 1 and pow(w, n // 2, p) == p - 1
    for s in range(n.bit_length() - 1):
        ln = n >> (s + 1)
        wm = pow(w, n // (2 * ln), p)
        for j in range(ln):
            assert int(wf[s, j]) * rinv % p == pow(wm, j, p), (s, j)
        ln_i = 1 << s
        wmi = pow(pow(w, -1, p), n // (2 * ln_i), p)
        for j in range(ln_i):
            assert int(wi[s, j]) * rinv % p == pow(wmi, j, p), (s, j)


@pytest.mark.parametrize("p", NK.PRIMES)
def test_forward_dif_matches_dft_ref(p):
    n = 32
    pinv = (-pow(p, -1, R)) % R
    x = RNG.integers(0, p, n, dtype=np.int64).astype(np.uint32)
    wf, _ = NO.twiddle_tables(p, n)
    got = np.asarray(NK.ntt_forward(jnp.asarray(x)[None, :],
                                    jnp.asarray(wf), p, pinv))[0]
    np.testing.assert_array_equal(got, NREF.ntt_fwd_ref(x, p))


def test_forward_inverse_roundtrip():
    """inv(fwd(x)) == x.  A pure roundtrip skips the pointwise stage, so
    the scale constant is N^-1 * R (one R to cancel its own mont_mul),
    not the production N^-1 * R^2 (which additionally cancels the
    pointwise product's stray R^-1)."""
    p = NK.PRIMES[0]
    n = 128
    pinv = (-pow(p, -1, R)) % R
    x = RNG.integers(0, p, (4, n), dtype=np.int64).astype(np.uint32)
    wf, wi = (jnp.asarray(t) for t in NO.twiddle_tables(p, n))
    f = NK.ntt_forward(jnp.asarray(x), wf, p, pinv)
    back = np.asarray(NK.ntt_inverse(f, wi, p, pinv,
                                     pow(n, -1, p) * R % p))
    np.testing.assert_array_equal(back, x)


# ---------------------------------------------------------------------------
# Garner CRT recombination vs Python ints.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nprimes", [2, 3])
def test_crt_combine_matches_python(nprimes):
    """Random coefficient vectors up to the worst-case bound: residues
    in, exact digit expansion out (one carry resolve)."""
    nd_out = 32
    prs = NK.PRIMES[:nprimes]
    bound = NO.coefficient_bound(nd_out)
    assert bound < np.prod([int(p) for p in prs], dtype=object)
    vals = [int(RNG.integers(0, 1 << 62)) * int(RNG.integers(0, 16)) % bound
            for _ in range(nd_out)]
    vals[0] = bound - 1                      # pin the extreme coefficient
    want = sum(v << (16 * j) for j, v in enumerate(vals))
    res = tuple(
        jnp.asarray(np.array([[v % p for v in vals]], np.uint32))
        for p in prs)
    got = np.asarray(NO.crt_combine(res, nd_out))[0]
    assert got.max() <= 0xFFFF
    assert L.limbs_to_int(got, 16) == want % (1 << (16 * nd_out))


def test_resolve_nprimes_validation():
    with pytest.raises(ValueError, match="must be 2 or 3"):
        NO._resolve_nprimes(64, 4)
    with pytest.raises(ValueError, match="overflow the 2-prime"):
        NO._resolve_nprimes(1 << 25, 2)      # past the 2-prime bound
    assert NO._resolve_nprimes(1 << 20, 2) == 2
    assert NO._resolve_nprimes(4096, None) in (2, 3)   # config default


# ---------------------------------------------------------------------------
# End-to-end oracles (the acceptance grid).  4096/8192 fast; >= 16384 slow.
# ---------------------------------------------------------------------------

def _check_ntt_mul(nbits, batch, nprimes):
    m = nbits // 32
    xs = L.random_bigints(RNG, batch, nbits)
    ys = L.random_bigints(RNG, batch, nbits)
    prod = np.asarray(NO.ntt_mul_limbs32(
        jnp.asarray(L.ints_to_batch(xs, m)),
        jnp.asarray(L.ints_to_batch(ys, m)), nprimes=nprimes))
    assert prod.shape == (batch, 2 * m)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(prod[i]) == x * y, (nbits, batch, nprimes, i)


@pytest.mark.parametrize("nbits,batch,nprimes", [
    (4096, 8, 2), (4096, 8, 3), (4096, 1, 2),
    (8192, 8, 2), (8192, 1, 3),
])
def test_ntt_mul_vs_python_int(nbits, batch, nprimes):
    _check_ntt_mul(nbits, batch, nprimes)


@pytest.mark.slow
@pytest.mark.parametrize("nbits,batch,nprimes", [
    (16384, 8, 2), (16384, 8, 3),
    (65536, 8, 2), (65536, 8, 3), (65536, 1, 2),
])
def test_ntt_mul_vs_python_int_wide(nbits, batch, nprimes):
    _check_ntt_mul(nbits, batch, nprimes)


def test_ntt_mul_pathological():
    """All-max operands hit the worst-case CRT coefficient bound."""
    nbits = 4096
    m = nbits // 32
    pairs = L.pathological_pairs(nbits)
    a = jnp.asarray(L.ints_to_batch([q[0] for q in pairs], m))
    b = jnp.asarray(L.ints_to_batch([q[1] for q in pairs], m))
    prod = np.asarray(NO.ntt_mul_limbs32(a, b, nprimes=2))
    for i, (x, y) in enumerate(pairs):
        assert L.limbs_to_int(prod[i]) == x * y, i


def test_ntt_mul_odd_batch_padding():
    """Non-tile batch exercises the pad/trim path; jnp Karatsuba ref.
    Width stays small: the ref's eager Karatsuba trace is the cost."""
    nbits, batch = 1024, 5
    m = nbits // 32
    xs = L.random_bigints(RNG, batch, nbits)
    ys = L.random_bigints(RNG, batch, nbits)
    a, b = L.ints_to_batch(xs, m), L.ints_to_batch(ys, m)
    got = np.asarray(NO.ntt_mul_limbs32(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(NREF.ntt_mul_limbs32_ref(a, b))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Dispatch: the "ntt" tier in core/mul.select_method + mul_limbs32.
# ---------------------------------------------------------------------------

def test_select_method_ntt_tier():
    from repro.configs.dot_bignum import MUL_DISPATCH as cfg
    B = 512
    assert M.select_method(cfg.ntt_min_bits, batch=B) == "ntt"
    assert M.select_method(65536, batch=B) == "ntt"
    assert M.select_method(cfg.ntt_min_bits - 32, batch=B) == "karatsuba"
    # huge operands take the NTT kernel even below the kernel batch
    # threshold (its compile stays flat where jnp Karatsuba's explodes)
    assert M.select_method(cfg.small_batch_dot_max_bits + 32,
                           batch=1) == "ntt"
    assert M.select_method(cfg.small_batch_dot_max_bits, batch=1) == "dot"
    # prefer_mxu cannot reach past the Toeplitz range
    assert M.select_method(65536, batch=B, prefer_mxu=True) == "ntt"


def test_ntt_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_MUL_BACKEND", "ntt")
    assert M.select_method(256, batch=1) == "ntt"


def test_mul_limbs32_auto_routes_ntt_exact():
    nbits, batch = 8192, 8
    m = nbits // 32
    assert M.select_method(nbits, batch=batch) == "ntt"
    xs = L.random_bigints(RNG, batch, nbits)
    ys = L.random_bigints(RNG, batch, nbits)
    p = np.asarray(M.mul_limbs32(jnp.asarray(L.ints_to_batch(xs, m)),
                                 jnp.asarray(L.ints_to_batch(ys, m)),
                                 method="auto"))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(p[i]) == x * y, i


def test_mul_limbs32_ntt_leading_batch_dims():
    nbits = 8192
    m = nbits // 32
    xs = L.random_bigints(RNG, 8, nbits)
    ys = L.random_bigints(RNG, 8, nbits)
    a = L.ints_to_batch(xs, m).reshape(2, 4, m)
    b = L.ints_to_batch(ys, m).reshape(2, 4, m)
    p = np.asarray(M.mul_limbs32(a, b, method="ntt"))
    assert p.shape == (2, 4, 2 * m)
    flat = p.reshape(8, 2 * m)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(flat[i]) == x * y, i


def test_unknown_method_error_lists_methods():
    a = L.ints_to_batch([3], 4)
    with pytest.raises(ValueError) as e:
        M.mul_limbs32(a, a, method="bogus")
    msg = str(e.value)
    for name in M.MUL_METHODS:
        assert name in msg
    assert "REPRO_MUL_BACKEND" in msg


# ---------------------------------------------------------------------------
# The division subsystem rides the tier automatically via method="auto".
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_divmod_wide_rides_ntt_tier():
    """8192-bit divmod: every Newton multiply above 4096 bits dispatches
    to the NTT kernel (batch-1 regime) and the result stays exact."""
    from repro.core import div as DV
    nbits_a, nbits_b = 8192, 4224
    ma, mb = nbits_a // 32, nbits_b // 32
    xs = L.random_bigints(RNG, 2, nbits_a)
    ys = [y | 1 for y in L.random_bigints(RNG, 2, nbits_b)]
    q, r = DV.divmod_limbs32(jnp.asarray(L.ints_to_batch(xs, ma)),
                             jnp.asarray(L.ints_to_batch(ys, mb)))
    q, r = np.asarray(q), np.asarray(r)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(q[i]) == x // y, i
        assert L.limbs_to_int(r[i]) == x % y, i
