"""Windowed (k-ary) modexp ladder: exponent edge cases, window sizes,
modmul-count bound, constant-time structure, and dispatch.

Every device backend (jnp Montgomery, Barrett, fused Pallas ladder)
runs the SAME fixed-window schedule; these tests pin its correctness
against the python-int oracle at 256-2048 bits for BOTH modulus
parities, assert the ~nbits*(1 + 1/w) + 2**w multiply count the window
restructuring exists for, and verify the ladder never branches on
exponent bits (identical compiled HLO for different exponent values).

Device calls are jitted: eagerly, every modular multiply re-traces its
inner carry scan (fresh closures), which is ~0.5 s/multiply of pure
compile overhead -- the jitted ladder compiles each call site once.
The multiply-count tests skip execution entirely (jax.make_jaxpr
traces the unrolled driver, where trace-time calls == runtime calls).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.dot_bignum import (MODEXP_DISPATCH, modexp_modmul_count,
                                      pick_modexp_window)
from repro.core import limbs as L
from repro.core import modular as M
from repro.kernels.common.windows import exponent_windows

RNG = np.random.default_rng(17)

DEVICE_BACKENDS = ("jnp", "pallas", "barrett", "barrett_fused")


def _modulus(nbits, parity="odd"):
    n = L.random_bigints(RNG, 1, nbits)[0] | (1 << (nbits - 1))
    return (n | 1) if parity == "odd" else (n & ~1)


def _ctx(n, nbits):
    return M.mont_setup(n, nbits) if n % 2 else M.barrett_setup(n, nbits)


def _digits(ints, m):
    return jnp.asarray(np.stack([L.int_to_limbs(v, m, 16) for v in ints]))


def _mod_exp_jit(a, eb, ctx, **kw):
    return jax.jit(lambda v, b: M.mod_exp(v, b, ctx, **kw))(a, eb)


def _check(out, xs, e, n):
    for i, x in enumerate(xs):
        assert L.limbs_to_int(np.asarray(out)[i], 16) == pow(x, e, n), i


# ---------------------------------------------------------------------------
# exponent edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_exponent_edge_cases(backend):
    """e=0 (-> 1), e=1 (-> x), all-ones exponent (every window maxed),
    and leading-zero bits (nbits >> e.bit_length) on every backend."""
    nbits = 192
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [0, 1, n - 1] + [v % n for v in L.random_bigints(RNG, 5, nbits)]
    a = _digits(xs, ctx.m)
    cases = [
        (0, 1),                      # e=0: result 1 even for base 0
        (1, 1),
        ((1 << 48) - 1, 48),         # all-ones: every table row exercised
        (5, 48),                     # 45 leading-zero bits
        (65537, 17),                 # the RSA public exponent
    ]
    for e, ebits in cases:
        eb = jnp.asarray(M.exp_bits_msb(e, ebits))
        out = _mod_exp_jit(a, eb, ctx, backend=backend)
        _check(out, xs, e, n)


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_per_lane_exponents(backend):
    """Batch of DISTINCT per-lane exponents (incl. 0/1/leading-zero
    lanes), shared modulus -- the throughput workload variant."""
    nbits = 192
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    xs = [v % n for v in L.random_bigints(RNG, 8, nbits)]
    es = [0, 1, 3, (1 << 48) - 1] + [int(v) | 1
                                     for v in L.random_bigints(RNG, 4, 48)]
    eb = jnp.asarray(np.stack([M.exp_bits_msb(e, 48) for e in es]))
    out = np.asarray(_mod_exp_jit(_digits(xs, ctx.m), eb, ctx,
                                  backend=backend))
    for i, (x, e) in enumerate(zip(xs, es)):
        assert L.limbs_to_int(out[i], 16) == pow(x, e, n), (i, e)


# ---------------------------------------------------------------------------
# window sizes vs the oracle, both modulus parities, 256-2048 bits
# ---------------------------------------------------------------------------

# (modulus bits, exponent bits): big widths are slow-marked and use a
# shorter exponent -- they pin digit-width correctness, which does not
# depend on ladder length (exponent structure is covered at 256/512).
WIDTHS = [(256, 96), pytest.param(512, 96, marks=pytest.mark.slow),
          pytest.param(1024, 32, marks=pytest.mark.slow),
          pytest.param(2048, 32, marks=pytest.mark.slow)]


@pytest.mark.parametrize("nbits,ebits", WIDTHS)
@pytest.mark.parametrize("parity", ["odd", "even"])
def test_window_sizes_vs_oracle(nbits, ebits, parity):
    """w in {1, 2, 4, 5} all agree with python pow at both parities
    (odd -> Montgomery windowed ladder, even -> Barrett windowed
    ladder via the auto-route)."""
    n = _modulus(nbits, parity)
    ctx = _ctx(n, nbits)
    e = int(L.random_bigints(RNG, 1, ebits)[0]) | (1 << (ebits - 1)) | 1
    eb = jnp.asarray(M.exp_bits_msb(e, ebits))
    xs = [v % n for v in L.random_bigints(RNG, 2, nbits)]
    a = _digits(xs, ctx.m)
    for w in (1, 2, 4, 5):
        out = _mod_exp_jit(a, eb, ctx, window=w,
                           backend="barrett" if parity == "even" else "jnp")
        _check(out, xs, e, n)


@pytest.mark.parametrize("nbits,ebits",
                         [(256, 256),
                          pytest.param(1024, 1024, marks=pytest.mark.slow),
                          pytest.param(2048, 2048, marks=pytest.mark.slow)])
def test_fused_ladder_full_width_oracle(nbits, ebits):
    """The fused Pallas ladder at full-width exponents (the RSA-sign
    shape); 1024/2048-bit are the slow-marked heavyweight oracles."""
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    e = int(L.random_bigints(RNG, 1, ebits)[0]) | (1 << (ebits - 1)) | 1
    eb = jnp.asarray(M.exp_bits_msb(e, ebits))
    xs = [v % n for v in L.random_bigints(RNG, 3, nbits)]
    out = _mod_exp_jit(_digits(xs, ctx.m), eb, ctx, backend="pallas")
    _check(out, xs, e, n)


@pytest.mark.parametrize("w", [1, 2, 4, 5])
def test_fused_ladder_window_sizes(w):
    """Window override reaches the kernel (one specialization per w)."""
    nbits = 128
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    e = int(L.random_bigints(RNG, 1, 40)[0]) | 1
    eb = jnp.asarray(M.exp_bits_msb(e, 40))
    xs = [v % n for v in L.random_bigints(RNG, 9, nbits)]
    out = _mod_exp_jit(_digits(xs, ctx.m), eb, ctx, backend="pallas",
                       window=w)
    _check(out, xs, e, n)


def test_shared_base_batched_exponents_auto_dispatch():
    """Fixed base (m,) x per-lane exponents (batch, nbits) on the
    DEFAULT backend: dispatch counts the exponent's batch dims, so the
    pallas branch must broadcast the base UP to the joint batch shape
    (the DH fixed-generator workload; regression -- this crashed when
    the fused-ladder branch flattened only the base's batch shape)."""
    nbits = 128
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    g = _digits([2], ctx.m)[0]                     # (m,): shared base
    es = [int(v) | 1 for v in L.random_bigints(RNG, 8, 48)]
    eb = jnp.asarray(np.stack([M.exp_bits_msb(e, 48) for e in es]))
    out = np.asarray(_mod_exp_jit(g, eb, ctx))     # batch 8 -> fused ladder
    for i, e in enumerate(es):
        assert L.limbs_to_int(out[i], 16) == pow(2, e, n), (i, e)


def test_window_zero_rejected():
    nbits = 128
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    a = _digits([2], ctx.m)
    eb = jnp.asarray(M.exp_bits_msb(5, 8))
    for w in (0, -1):
        with pytest.raises(ValueError, match="window"):
            M.mod_exp(a, eb, ctx, backend="jnp", window=w)


def test_unrolled_ladder_matches_scan():
    """unroll=True (the call-counting path) and the lax.scan window loop
    are the same schedule -- bit-identical digits."""
    nbits, ebits, w = 128, 16, 4
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    e = int(L.random_bigints(RNG, 1, ebits)[0]) | 1
    eb = jnp.asarray(M.exp_bits_msb(e, ebits))
    xs = [v % n for v in L.random_bigints(RNG, 4, nbits)]
    a = _digits(xs, ctx.m)
    got_u = np.asarray(jax.jit(
        lambda v: M._mod_exp_jnp(v, eb, ctx, window=w, unroll=True))(a))
    got_s = np.asarray(jax.jit(
        lambda v: M._mod_exp_jnp(v, eb, ctx, window=w))(a))
    np.testing.assert_array_equal(got_u, got_s)
    _check(got_s, xs, e, n)


# ---------------------------------------------------------------------------
# modmul-count bound (the point of the window restructuring)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 2, 4, 5])
@pytest.mark.parametrize("ebits", [64, 96])
def test_modmul_count_bound(w, ebits, monkeypatch):
    """The windowed ladder performs <= nbits*(1 + 1/w) + 2**w modular
    multiplies (vs ~2*nbits for the PR-3 bit-serial ladder), counted by
    intercepting the backend multiply while TRACING the unrolled driver
    (jax.make_jaxpr: trace-time calls == runtime multiplies there, no
    execution; scan/unroll equivalence is pinned by
    test_unrolled_ladder_matches_scan)."""
    nbits = 128
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    e = int(L.random_bigints(RNG, 1, ebits)[0]) | (1 << (ebits - 1))
    eb = jnp.asarray(M.exp_bits_msb(e, ebits))
    a = _digits([v % n for v in L.random_bigints(RNG, 2, nbits)], ctx.m)

    calls = {"n": 0}
    real = M._mont_mul_jnp

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(M, "_mont_mul_jnp", counting)
    jax.make_jaxpr(
        lambda v: M._mod_exp_jnp(v, eb, ctx, window=w, unroll=True))(a)
    bound = ebits * (1 + 1 / w) + (1 << w)
    # +2: the Montgomery domain entry/exit multiplies (to_mont/from_mont)
    assert calls["n"] == modexp_modmul_count(ebits, w) + 2
    assert calls["n"] <= bound, (calls["n"], bound)
    if w >= 4:
        # decisively under the bit-serial ladder's 2 multiplies per bit
        assert calls["n"] < 2 * ebits


def test_barrett_ladder_count_bound(monkeypatch):
    """Barrett runs the same schedule with no domain transforms."""
    nbits, ebits, w = 128, 64, 4
    n = _modulus(nbits, "even")
    ctx = M.barrett_setup(n, nbits)
    e = int(L.random_bigints(RNG, 1, ebits)[0]) | (1 << (ebits - 1))
    eb = jnp.asarray(M.exp_bits_msb(e, ebits))
    a = _digits([v % n for v in L.random_bigints(RNG, 2, nbits)], ctx.m)

    calls = {"n": 0}
    real = M.barrett_mod_mul

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(M, "barrett_mod_mul", counting)
    jax.make_jaxpr(
        lambda v: M._barrett_mod_exp(v, eb, ctx, window=w, unroll=True))(a)
    assert calls["n"] == modexp_modmul_count(ebits, w)
    assert calls["n"] <= ebits * (1 + 1 / w) + (1 << w)


# ---------------------------------------------------------------------------
# constant-time structure
# ---------------------------------------------------------------------------

def _branch_prims(jaxpr, acc):
    """Collect cond/switch primitive names appearing anywhere in a
    (closed) jaxpr, recursing into sub-jaxprs (scan/while bodies)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in ("cond", "switch"):
            acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            objs = v if isinstance(v, (list, tuple)) else (v,)
            for o in objs:
                if hasattr(o, "eqns"):            # raw Jaxpr
                    _branch_prims(o, acc)
                elif hasattr(o, "jaxpr"):         # ClosedJaxpr
                    _branch_prims(o.jaxpr, acc)
    return acc


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_identical_hlo_for_different_exponents(backend):
    """No data-dependent branching on exponent bits: different exponent
    VALUES (same width) must compile to identical HLO, and -- the check
    with teeth, since a traced exponent can never change the lowering
    by construction -- the ladder's jaxpr must contain NO cond/switch
    primitives at all: the exponent only ever feeds branch-free table
    gathers/selects.  (Barrett is exempt from the structural check: its
    reduction uses a bounded while-loop correction keyed on residue
    magnitude, not on exponent bits.)"""
    nbits, ebits = 128, 32
    n = _modulus(nbits)
    ctx = M.mont_setup(n, nbits)
    a = _digits([v % n for v in L.random_bigints(RNG, 8, nbits)], ctx.m)

    def f(x, eb):
        return M.mod_exp(x, eb, ctx, backend=backend)

    texts = []
    for e in (0, 1, 65537, (1 << 32) - 1):
        eb = jnp.asarray(M.exp_bits_msb(e, ebits))
        texts.append(jax.jit(f).lower(a, eb).compile().as_text())
    assert texts[0] == texts[1] == texts[2] == texts[3]
    if backend != "barrett":
        eb = jnp.asarray(M.exp_bits_msb(65537, ebits))
        prims = _branch_prims(jax.make_jaxpr(f)(a, eb).jaxpr, set())
        assert not prims, f"data-dependent branching found: {prims}"


# ---------------------------------------------------------------------------
# dispatch + helpers
# ---------------------------------------------------------------------------

def test_select_modexp_backend_batch_aware():
    cfg = MODEXP_DISPATCH
    big = cfg.fused_min_batch
    small = cfg.packed_min_batch
    assert M.select_modexp_backend(512, batch=big, ebits=512) == "pallas"
    # sub-tile batches still take the fused ladder: the kernel wrappers
    # pad the batch up to the tile minimum (sub-batch lane packing), so
    # the floor is packed_min_batch, not a full tile
    assert M.select_modexp_backend(512, batch=small, ebits=512) == "pallas"
    assert M.select_modexp_backend(512, batch=small - 1, ebits=512) == "jnp"
    # tiny exponents: table build dominates, kernel launch can't pay
    assert M.select_modexp_backend(
        512, batch=big, ebits=cfg.fused_min_exp_bits - 1) == "jnp"
    # beyond the kernel's VMEM bound
    assert M.select_modexp_backend(
        cfg.fused_max_bits + 16, batch=big, ebits=512) == "jnp"
    # even modulus: the fused Barrett ladder in the same packed regime,
    # the jnp Barrett composition below it
    bctx = M.barrett_setup(_modulus(128, "even"), 128)
    assert M.select_modexp_backend(128, batch=big, ebits=128,
                                   ctx=bctx) == "barrett_fused"
    assert M.select_modexp_backend(128, batch=small - 1, ebits=128,
                                   ctx=bctx) == "barrett"


def test_modexp_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_MODEXP_BACKEND", "jnp")
    assert M.select_modexp_backend(512, batch=64, ebits=512) == "jnp"
    monkeypatch.setenv("REPRO_MODEXP_BACKEND", "nope")
    with pytest.raises(ValueError, match="REPRO_MODEXP_BACKEND"):
        M.select_modexp_backend(512, batch=64, ebits=512)


def test_pick_modexp_window():
    assert pick_modexp_window(1024) == MODEXP_DISPATCH.window_bits
    assert pick_modexp_window(1) == 1
    # short exponents get small windows (w=4's 14-multiply table build
    # would cost more than it saves at e = 65537)
    w17 = pick_modexp_window(17)
    assert w17 < 4
    assert modexp_modmul_count(17, w17) <= modexp_modmul_count(17, 1)
    with pytest.raises(ValueError):
        modexp_modmul_count(64, 0)


def test_exponent_windows_packing():
    """Window values must re-assemble to the exponent (MSB-first, LSB-
    aligned windows) for w dividing and not dividing nbits."""
    e = 0b1011_0110_001
    for w in (1, 3, 4, 5):
        eb = jnp.asarray(M.exp_bits_msb(e, 11))
        wv = np.asarray(exponent_windows(eb, w))
        got = 0
        for d in wv:
            got = (got << w) | int(d)
        assert got == e, w


def test_exp_bits_msb_rejects_truncation():
    with pytest.raises(ValueError, match="truncate"):
        M.exp_bits_msb(65537, 16)
    with pytest.raises(ValueError, match=">= 0"):
        M.exp_bits_msb(-1)
    np.testing.assert_array_equal(
        M.exp_bits_msb(5, 6), np.array([0, 0, 0, 1, 0, 1], np.uint32))


def test_default_dispatch_used_by_rsa():
    """rsa.sign with backend=None routes through the batch-aware
    dispatch and still matches the python oracle (small batch -> jnp
    windowed; kernel-sized batch -> fused pallas ladder)."""
    from repro.core import rsa as R
    key = R.generate_key(bits=192, seed=3)
    msgs = [R.digest_int(f"w{i}".encode(), key.bits) for i in range(8)]
    md = R.messages_to_digits(msgs, key)
    sigs = np.asarray(jax.jit(lambda x: R.sign(x, key))(md))  # batch 8: fused
    for i, m in enumerate(msgs):
        assert L.limbs_to_int(sigs[i], 16) == pow(m % key.n, key.d, key.n), i
    env = os.environ.get("REPRO_MODEXP_BACKEND")
    assert env is None, "test assumes no backend override in the env"
