"""serve/bignum_engine: shape bucketing, the no-retrace contract,
flush policy (batch-full vs deadline), padding, and batched == one-at-
a-time determinism.  Everything runs at tiny widths on the jnp backend
so the compiles stay cheap; the replay-policy tests stub out the
device work entirely and drive the virtual clock by hand."""
import random

import numpy as np
import pytest

from repro import api
from repro.configs.dot_bignum import SERVE, ServeConfig, quantize_bits
from repro.serve import bignum_engine as BE

PY = random.Random(99)


def _odd(bits):
    return PY.getrandbits(bits) | 1 | (1 << (bits - 1))


def _mod_exp_req(rid, n, e=None):
    e = e if e is not None else PY.getrandbits(24) | 1
    base = PY.randrange(2, n)
    return BE.BignumRequest(rid=rid, op="mod_exp",
                            value=api.to_limbs(base, n.bit_length()),
                            modulus=n, exponent=e)


def _oracle(r):
    return pow(int(api.from_limbs(np.asarray(r.value))), r.exponent,
               r.modulus)


SMALL = ServeConfig(bucket_bits=(96, 160), exp_bucket_bits=(16, 32, 64),
                    slots=4, max_wait_s=0.02)


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

def test_quantize_bits():
    assert quantize_bits(1, (256, 512)) == 256
    assert quantize_bits(256, (256, 512)) == 256
    assert quantize_bits(257, (256, 512)) == 512
    assert quantize_bits(300, SERVE.bucket_bits) == 512
    with pytest.raises(ValueError, match="choose from"):
        quantize_bits(600, (256, 512))
    with pytest.raises(ValueError):
        quantize_bits(0, (256,))


def test_bucket_key_quantizes_widths():
    eng = BE.BignumEngine(SMALL)
    n80, n90, n150 = _odd(80), _odd(90), _odd(150)
    k80 = eng.bucket_key(_mod_exp_req(0, n80, e=3))
    k90 = eng.bucket_key(_mod_exp_req(1, n90, e=3))
    k150 = eng.bucket_key(_mod_exp_req(2, n150, e=3))
    # same modulus bucket iff same (width tier, exp tier, modulus)
    assert k80[:3] == k90[:3] == ("mod_exp", 96, 16)
    assert k80 != k90                    # modulus is part of the key
    assert k150[1] == 160
    key = api.generate_key(96, seed=5)
    krsa = eng.bucket_key(BE.BignumRequest(
        rid=3, op="rsa_sign", value=np.zeros(3, np.uint32), key=key))
    assert krsa == ("rsa_sign", 96, None, key.n)   # natural width


def test_unknown_op_message():
    eng = BE.BignumEngine(SMALL)
    with pytest.raises(ValueError) as e:
        eng.bucket_key(BE.BignumRequest(rid=0, op="frobnicate",
                                        value=np.zeros(1, np.uint32)))
    msg = str(e.value)
    assert "frobnicate" in msg
    for op in BE.OPS:
        assert op in msg


# ---------------------------------------------------------------------------
# replay policy on a stubbed engine (no device work, hand-driven clock)
# ---------------------------------------------------------------------------

def _stub(engine):
    lw = max(engine.cfg.bucket_bits) // 32
    engine._execute = lambda bkey, reqs: np.zeros(
        (engine.cfg.slots, lw), np.uint32)
    return engine


def test_full_flush_on_slots_submissions():
    eng = _stub(BE.BignumEngine(SMALL))
    n = _odd(80)
    done = []
    for i in range(SMALL.slots):
        done += eng.submit(_mod_exp_req(i, n, e=5), now=0.001 * i)
    assert [r.rid for r in done] == list(range(SMALL.slots))
    assert eng.stats.flush_full == 1 and eng.stats.flush_deadline == 0
    assert eng.stats.padded_lanes == 0 and eng.pending() == 0


def test_deadline_flush_pads_partial_batch():
    eng = _stub(BE.BignumEngine(SMALL))
    n = _odd(80)
    assert eng.submit(_mod_exp_req(0, n, e=5), now=1.0) == []
    assert eng.submit(_mod_exp_req(1, n, e=5), now=1.005) == []
    # deadline comes from the OLDEST request in the bucket
    assert eng.next_deadline() == pytest.approx(1.0 + SMALL.max_wait_s)
    assert eng.flush_next_due(1.0 + SMALL.max_wait_s / 2) == []
    done = eng.flush_next_due(1.0 + SMALL.max_wait_s)
    assert [r.rid for r in done] == [0, 1]
    assert eng.stats.flush_deadline == 1
    assert eng.stats.padded_lanes == SMALL.slots - 2
    assert eng.next_deadline() is None


def test_replay_deadline_vs_full_regimes():
    n = _odd(80)
    tmpl = [dict(op="mod_exp", value=api.to_limbs(2, 80), modulus=n,
                 exponent=7)]
    # sparse arrivals (mean gap 10x max_wait): every flush is a deadline
    eng = _stub(BE.BignumEngine(SMALL))
    res = BE.replay_trace(eng, BE.poisson_trace(
        tmpl, 8, rate_per_s=1.0 / (10 * SMALL.max_wait_s), seed=2))
    assert res.n == 8 and eng.stats.flush_full == 0
    assert eng.stats.flush_deadline > 0
    # every lone request waits out its deadline before being served
    assert res.p50_ms >= SMALL.max_wait_s * 1e3
    # dense arrivals (mean gap max_wait/100): batches fill
    eng2 = _stub(BE.BignumEngine(SMALL))
    res2 = BE.replay_trace(eng2, BE.poisson_trace(
        tmpl, 16, rate_per_s=100.0 / SMALL.max_wait_s, seed=3))
    assert res2.n == 16 and eng2.stats.flush_full == 16 // SMALL.slots


# ---------------------------------------------------------------------------
# real compute: no-retrace contract, correctness, determinism
# ---------------------------------------------------------------------------

def test_mixed_shape_trace_zero_retraces_after_warm():
    eng = BE.BignumEngine(SMALL, backend="jnp")
    n1, n2 = _odd(80), _odd(150)      # distinct width tiers
    e = 0x10001
    eng.warm("mod_exp", modulus=n1, exponent=e)
    eng.warm("mod_exp", modulus=n2, exponent=e)
    assert eng.stats.programs == 2
    after_warm = eng.stats.traces
    reqs = [_mod_exp_req(i, n1 if i % 2 == 0 else n2, e=e)
            for i in range(10)]
    tmpl = [dict(op=r.op, value=r.value, modulus=r.modulus,
                 exponent=r.exponent) for r in reqs]
    res = BE.replay_trace(eng, BE.poisson_trace(tmpl, 10, 500.0, seed=4))
    assert res.n == 10
    assert eng.stats.traces == after_warm, (
        f"engine retraced on a warmed mixed-shape trace: {eng.stats}")
    # and a second identical trace stays flat too
    BE.replay_trace(eng, BE.poisson_trace(tmpl, 10, 500.0, seed=5))
    assert eng.stats.traces == after_warm


def test_batched_equals_one_at_a_time_and_oracle():
    n = _odd(90)
    reqs = [_mod_exp_req(i, n) for i in range(6)]
    eng = BE.BignumEngine(SMALL, backend="jnp")
    done = []
    for r in reqs:
        done += eng.submit(r, now=0.0)
    while eng.pending():
        done += eng.drain_one()
    assert sorted(r.rid for r in done) == list(range(6))
    naive = BE.NaiveServer(backend="jnp")
    for r in reqs:
        want = _oracle(r)
        assert int(api.from_limbs(r.result)) == want, r.rid
        single = BE.BignumRequest(rid=r.rid, op=r.op, value=r.value,
                                  modulus=r.modulus, exponent=r.exponent)
        naive.serve(single)
        assert int(api.from_limbs(single.result)) == want, r.rid
    # 6 reqs over 4 slots: one full flush + one padded drain
    assert eng.stats.flush_full == 1 and eng.stats.padded_lanes == 2


def test_rsa_ops_through_engine():
    key = api.generate_key(128, seed=11)
    msg = api.digest_int(b"engine", key.bits) % key.n
    cfg = ServeConfig(bucket_bits=(128,), exp_bucket_bits=(256,),
                      slots=2, max_wait_s=0.01)
    eng = BE.BignumEngine(cfg, backend="jnp")
    sig_req = BE.BignumRequest(rid=0, op="rsa_sign",
                               value=api.to_limbs(msg, key.bits), key=key)
    ver_req = BE.BignumRequest(
        rid=1, op="rsa_verify",
        value=api.to_limbs(pow(msg, key.d, key.n), key.bits), key=key)
    dec_req = BE.BignumRequest(
        rid=2, op="rsa_decrypt",
        value=api.to_limbs(pow(msg, key.e, key.n), key.bits), key=key)
    done = []
    for r in (sig_req, ver_req, dec_req):
        done += eng.submit(r, now=0.0)
    while eng.pending():
        done += eng.drain_one()
    assert len(done) == 3
    assert int(api.from_limbs(sig_req.result)) == pow(msg, key.d, key.n)
    assert int(api.from_limbs(ver_req.result)) == msg
    assert int(api.from_limbs(dec_req.result)) == msg
    # three ops -> three distinct programs, all padded singleton batches
    assert eng.stats.programs == 3 and eng.stats.padded_lanes == 3


# ---------------------------------------------------------------------------
# fault tolerance: lifecycle, shedding, retry, degradation, selfcheck
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _resilience_clean():
    from repro import config
    from repro.resilience import inject
    from repro.resilience.breaker import BREAKER
    inject.clear()
    BREAKER.reset()
    yield
    inject.clear()
    BREAKER.reset()
    config.set_overrides({"selfcheck": None})


def test_warm_is_idempotent_per_bucket():
    eng = BE.BignumEngine(SMALL, backend="jnp")
    n = _odd(80)
    eng.warm("mod_exp", modulus=n, exponent=0x10001)
    traces = eng.stats.traces
    eng.warm("mod_exp", modulus=n, exponent=0x10001)   # no-op: no retrace
    assert eng.stats.traces == traces
    assert eng.stats.programs == 1


def test_close_lifecycle():
    eng = _stub(BE.BignumEngine(SMALL))
    n = _odd(80)
    eng.submit(_mod_exp_req(0, n, e=5), now=0.0)
    done = eng.close()                     # drains the pending request
    assert [r.rid for r in done] == [0] and not done[0].shed
    assert eng.close() == []               # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(_mod_exp_req(1, n, e=5), now=0.0)
    with pytest.raises(RuntimeError, match="closed"):
        eng.warm("mod_exp", modulus=n, exponent=5)


def test_close_without_drain_sheds():
    eng = _stub(BE.BignumEngine(SMALL))
    n = _odd(80)
    eng.submit(_mod_exp_req(0, n, e=5), now=0.0)
    done = eng.close(drain=False)
    assert len(done) == 1 and done[0].shed and done[0].result is None
    assert eng.stats.shed == 1 and eng.pending() == 0


def test_submit_sheds_on_queue_bound():
    cfg = ServeConfig(bucket_bits=SMALL.bucket_bits,
                      exp_bucket_bits=SMALL.exp_bucket_bits,
                      slots=4, max_wait_s=10.0, max_queue=2)
    eng = _stub(BE.BignumEngine(cfg))
    n = _odd(80)
    assert eng.submit(_mod_exp_req(0, n, e=5), now=0.0) == []
    assert eng.submit(_mod_exp_req(1, n, e=5), now=0.0) == []
    out = eng.submit(_mod_exp_req(2, n, e=5), now=0.0)
    assert len(out) == 1 and out[0].shed and out[0].result is None
    assert eng.stats.shed == 1 and eng.pending() == 2


def test_submit_sheds_when_deadline_slips():
    eng = _stub(BE.BignumEngine(SMALL))
    n = _odd(80)
    eng.submit(_mod_exp_req(0, n, e=5), now=0.0)
    # arrival far past the oldest deadline + max_wait: overloaded
    out = eng.submit(_mod_exp_req(1, n, e=5), now=10 * SMALL.max_wait_s)
    assert len(out) == 1 and out[0].shed


def _flaky_stub(engine, fail_times, exc=None):
    """_execute fails the first ``fail_times`` calls, then succeeds."""
    lw = max(engine.cfg.bucket_bits) // 32
    calls = {"n": 0}

    def execute(bkey, reqs):
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise exc or RuntimeError("transient flush failure")
        return np.zeros((engine.cfg.slots, lw), np.uint32)

    engine._execute = execute
    return calls


def test_flush_retries_then_succeeds():
    eng = BE.BignumEngine(SMALL)
    calls = _flaky_stub(eng, fail_times=2)        # max_retries=2 absorbs
    n = _odd(80)
    eng.submit(_mod_exp_req(0, n, e=5), now=0.0)
    done = eng.drain_one()
    assert [r.rid for r in done] == [0]
    assert calls["n"] == 3 and eng.stats.retries == 2
    assert eng.stats.degraded == 0


def test_flush_degrades_bucket_after_retries():
    eng = BE.BignumEngine(SMALL, backend=None)
    calls = _flaky_stub(eng, fail_times=3)        # retries exhausted once
    n = _odd(80)
    req = _mod_exp_req(0, n, e=5)
    eng.submit(req, now=0.0)
    done = eng.drain_one()
    assert [r.rid for r in done] == [0]
    bkey = eng.bucket_key(req)
    assert eng._degraded[bkey] == "jnp"           # auto -> jnp
    assert eng.stats.degraded == 1 and eng.stats.retries == 2
    assert calls["n"] == 4                        # 3 failures + 1 at jnp


def test_degradation_ladder_reaches_reference():
    eng = BE.BignumEngine(SMALL, backend="jnp")
    n = _odd(80)
    req = _mod_exp_req(0, n, e=5)
    bkey = eng.bucket_key(req)
    assert eng._next_tier(bkey) == "reference"    # jnp degrades straight
    eng._degraded[bkey] = "reference"
    assert eng._next_tier(bkey) is None           # floor: nothing below
    # the reference tier serves exactly (host python-int, no jit)
    eng.submit(req, now=0.0)
    done = eng.drain_one()
    assert int(api.from_limbs(done[0].result)) == _oracle(req)
    assert eng.stats.traces == 0                  # never touched jax


def test_warm_partial_failure_degrades_not_fatal():
    eng = BE.BignumEngine(SMALL, backend="jnp")
    n = _odd(80)
    calls = {"n": 0}
    real = eng._execute

    def flaky(bkey, reqs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("warm-time compile blew up")
        return real(bkey, reqs)

    eng._execute = flaky
    eng.warm("mod_exp", modulus=n, exponent=0x10001)   # degraded, not fatal
    bkey = eng.bucket_key(BE.BignumRequest(
        rid=-1, op="mod_exp", value=np.zeros(1, np.uint32), modulus=n,
        exponent=0x10001))
    assert eng._degraded[bkey] == "reference"     # jnp -> reference
    assert eng.stats.degraded == 1
    req = _mod_exp_req(0, n, e=0x10001)
    eng.submit(req, now=0.0)
    done = eng.drain_one()
    assert int(api.from_limbs(done[0].result)) == _oracle(req)


def test_deadline_miss_counter():
    eng = _stub(BE.BignumEngine(SMALL))
    n = _odd(80)
    r0 = _mod_exp_req(0, n, e=5)
    r0.sla_s = 1e-9                               # impossible SLA
    r1 = _mod_exp_req(1, n, e=5)
    r1.sla_s = 1e9                                # unmissable SLA
    eng.submit(r0, now=0.0)
    eng.submit(r1, now=0.0)
    eng.drain_one()
    assert eng.stats.deadline_misses == 1


def test_corrupt_injection_caught_and_repaired():
    from repro import config
    from repro.resilience import inject
    config.set_overrides({"selfcheck": "warn"})
    inject.install("corrupt", "serve/flush", seed=3)
    eng = BE.BignumEngine(SMALL, backend="jnp")
    n = _odd(80)
    reqs = [_mod_exp_req(i, n, e=0x10001) for i in range(SMALL.slots)]
    done = []
    with pytest.warns(Warning, match="selfcheck"):
        for r in reqs:
            done += eng.submit(r, now=0.0)
    assert len(done) == SMALL.slots
    n_corrupt = sum(1 for e in inject.log() if e["kind"] == "corrupt")
    assert n_corrupt == 1
    assert eng.stats.selfcheck_failures == 1
    for r in reqs:                                # repaired: all exact
        assert int(api.from_limbs(r.result)) == _oracle(r)
