"""The unified multiply pipeline: fused Karatsuba + MXU Toeplitz kernels
vs Python-int ground truth, core/mul.py dispatch coverage, the shared
tile heuristics/autotuner, and (with hypothesis) the lazy-digit
normalization invariant that licenses the kernels' single end resolve.

Kernel oracle tests run the Pallas kernels in interpret mode on CPU;
widths above 1024 bits are slow-marked (interpret-mode tracing cost),
matching the CI fast-subset policy.
"""
import numpy as np
import pytest

import repro.core.mul as M
from repro.core import limbs as L
from repro.kernels.common import autotune, tiling
from repro.kernels.common.carry import normalize_static
from repro.kernels.kara_mul import ops as kara_ops
from repro.kernels.mxu_mul import ops as mxu_ops

RNG = np.random.default_rng(7)

WIDTH_MARKS = [512, 1024,
               pytest.param(2048, marks=pytest.mark.slow),
               pytest.param(4096, marks=pytest.mark.slow)]


def _digits16(ints, nd):
    return np.stack([L.int_to_limbs(v, nd, 16) for v in ints])


# ---------------------------------------------------------------------------
# Fused Karatsuba kernel vs Python ints (every tested width).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", WIDTH_MARKS)
def test_kara_kernel_vs_python_int(nbits):
    nd = nbits // 16
    xs = L.random_bigints(RNG, 5, nbits)
    ys = L.random_bigints(RNG, 5, nbits)
    p = np.asarray(kara_ops.kara_mul_digits(_digits16(xs, nd),
                                            _digits16(ys, nd)))
    assert p.shape == (5, 2 * nd)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(p[i], 16) == x * y, i


def test_kara_kernel_pathological():
    nbits = 1024
    nd = nbits // 16
    pairs = L.pathological_pairs(nbits, bits=16)
    p = np.asarray(kara_ops.kara_mul_digits(
        _digits16([q[0] for q in pairs], nd),
        _digits16([q[1] for q in pairs], nd)))
    for i, (x, y) in enumerate(pairs):
        assert L.limbs_to_int(p[i], 16) == x * y, i


def test_kara_kernel_vs_jnp_ref_and_batch_padding():
    """Odd batch exercises the tile-padding path; jnp Karatsuba is the
    secondary oracle."""
    from repro.kernels.kara_mul import ref
    nbits, batch = 768, 11        # 48 digits: a single-leaf (non-split) case
    nd = nbits // 16
    xs = L.random_bigints(RNG, batch, nbits)
    ys = L.random_bigints(RNG, batch, nbits)
    a, b = _digits16(xs, nd), _digits16(ys, nd)
    got = np.asarray(kara_ops.kara_mul_digits(a, b))
    want = np.asarray(ref.kara_mul_digits_ref(a, b))[..., : 2 * nd]
    np.testing.assert_array_equal(got, want)


def test_kara_kernel_base_modes_agree():
    nbits = 1024
    nd = nbits // 16
    xs = L.random_bigints(RNG, 4, nbits)
    ys = L.random_bigints(RNG, 4, nbits)
    a, b = _digits16(xs, nd), _digits16(ys, nd)
    rows = np.asarray(kara_ops.kara_mul_digits(a, b, base_mode="rows"))
    skew = np.asarray(kara_ops.kara_mul_digits(a, b, base_mode="skew"))
    np.testing.assert_array_equal(rows, skew)


# ---------------------------------------------------------------------------
# MXU Toeplitz kernel vs Python ints (every tested width).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", WIDTH_MARKS)
def test_mxu_kernel_vs_python_int(nbits):
    nd = -(-nbits // 7)
    xs = L.random_bigints(RNG, 5, nbits)
    ys = L.random_bigints(RNG, 5, nbits)
    a = np.stack([L.int_to_limbs(x, nd, 7, np.int8) for x in xs])
    b = np.stack([L.int_to_limbs(y, nd, 7, np.int8) for y in ys])
    p = np.asarray(mxu_ops.mxu_mul_digits(a, b))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(p[i], 7) == x * y, i


def test_mxu_kernel_limbs32_roundtrip():
    nbits = 512
    m = nbits // 32
    xs = L.random_bigints(RNG, 6, nbits)
    ys = L.random_bigints(RNG, 6, nbits)
    p = np.asarray(mxu_ops.mxu_mul_limbs32(
        L.ints_to_batch(xs, m), L.ints_to_batch(ys, m)))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(p[i], 32) == x * y, i


# ---------------------------------------------------------------------------
# core/mul.py dispatch: every branch of select_method + mul_limbs32.
# ---------------------------------------------------------------------------

def test_select_method_branches():
    B = 512                       # batch large enough to amortize a launch
    assert M.select_method(128, batch=B) == "dot"
    assert M.select_method(256, batch=B) == "dot"
    assert M.select_method(512, batch=B) == "pallas"
    assert M.select_method(1024, batch=B) == "pallas_kara"
    assert M.select_method(4096, batch=B) == "pallas_kara"
    assert M.select_method(6144, batch=B) == "karatsuba"
    assert M.select_method(8192, batch=B) == "ntt"
    assert M.select_method(1024, batch=B, prefer_mxu=True) == "pallas_mxu"
    assert M.select_method(6144, batch=B, prefer_mxu=True) == "karatsuba"
    assert M.select_method(8192, batch=B, prefer_mxu=True) == "ntt"


def test_select_method_small_batch_avoids_kernels():
    """Launches only amortize over the batch axis: tiny batches take the
    jnp compositions (and dodge interpret-mode compile cost on CPU).
    The NTT kernel is the one exception -- its O(log n) trace compiles
    in seconds at any width, so huge small-batch operands take it
    instead of the jnp Karatsuba composition (whose compile explodes
    past 4096 bits; see test_ntt_mul.py for the tier's own coverage)."""
    from repro.configs.dot_bignum import MUL_DISPATCH as cfg
    small = cfg.kernel_min_batch - 1
    assert M.select_method(1024, batch=small) == "dot"
    assert M.select_method(cfg.small_batch_dot_max_bits,
                           batch=small) == "dot"
    assert M.select_method(cfg.small_batch_dot_max_bits + 32,
                           batch=small) == "ntt"
    assert M.select_method(1024, batch=cfg.kernel_min_batch) == "pallas_kara"


def test_select_method_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_MUL_BACKEND", "schoolbook")
    assert M.select_method(1024) == "schoolbook"
    monkeypatch.setenv("REPRO_MUL_BACKEND", "bogus")
    with pytest.raises(ValueError):
        M.select_method(1024)


@pytest.mark.parametrize("nbits,method", [
    (256, "dot"),            # auto at this width
    (512, "pallas"),
    (1024, "pallas_kara"),
    (1024, "pallas_mxu"),
    (1024, "auto"),          # routes to pallas_kara
])
def test_mul_limbs32_dispatch_exact(nbits, method):
    m = nbits // 32
    xs = L.random_bigints(RNG, 4, nbits)
    ys = L.random_bigints(RNG, 4, nbits)
    p = np.asarray(M.mul_limbs32(L.ints_to_batch(xs, m),
                                 L.ints_to_batch(ys, m), method=method))
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(p[i], 32) == x * y, (method, i)


def test_mul_limbs32_auto_leading_batch_dims():
    """auto + a pallas route must survive (..., m) leading batch shapes."""
    nbits = 1024
    m = nbits // 32
    xs = L.random_bigints(RNG, 6, nbits)
    ys = L.random_bigints(RNG, 6, nbits)
    a = L.ints_to_batch(xs, m).reshape(2, 3, m)
    b = L.ints_to_batch(ys, m).reshape(2, 3, m)
    p = np.asarray(M.mul_limbs32(a, b, method="auto"))
    assert p.shape == (2, 3, 2 * m)
    flat = p.reshape(6, 2 * m)
    for i, (x, y) in enumerate(zip(xs, ys)):
        assert L.limbs_to_int(flat[i], 32) == x * y, i


# ---------------------------------------------------------------------------
# Shared tiling heuristics + the autotune cache.
# ---------------------------------------------------------------------------

def test_tiling_heuristic_bounds():
    budget = tiling.budget_words(6)
    for m in (1, 8, 64, 1024, 8192):
        for batch in (1, 7, 512, 100000):
            tb = tiling.batch_tile(m, batch, budget=budget)
            assert tiling.MIN_TILE <= tb <= tiling.DEFAULT_MAX_TILE
            assert tb <= max(tiling.MIN_TILE, batch)
    # monotone: more live arrays -> no larger tile
    assert tiling.batch_tile(64, 4096, budget=tiling.budget_words(24)) <= \
        tiling.batch_tile(64, 4096, budget=tiling.budget_words(6))


def test_autotune_disabled_returns_heuristic(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    calls = []
    tb = autotune.pick_tile("t", (8, 64, 16), 32, 64,
                            run=lambda t: calls.append(t))
    assert tb == 32 and calls == []


def test_autotune_sweeps_and_caches(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    autotune.clear_cache()
    calls = []

    def fake_run(t):
        calls.append(t)
        import time
        # margin must dwarf scheduler jitter on a loaded machine
        time.sleep(0.001 if t == 16 else 0.03)    # make 16 the winner
        return np.zeros(())

    key = ("unit", 999, 16)
    best = autotune.pick_tile("unit_op", key, 8, 999, run=fake_run, iters=1)
    assert best == 16
    assert set(calls) >= {8, 16}
    assert autotune.cache_summary() == {("unit_op",) + key: 16}
    calls.clear()
    again = autotune.pick_tile("unit_op", key, 8, 999, run=fake_run, iters=1)
    assert again == 16 and calls == []            # cached, no re-sweep
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# Lazy-digit normalization invariant (hypothesis): value preserved and
# output normalized, for the kernel-safe static resolve at 16 and 7 bits.
# ---------------------------------------------------------------------------

def _lazy_value(cols, bits):
    return sum(int(c) << (bits * i) for i, c in enumerate(cols))


def _check_normalize(cols, bits, bound):
    cols = np.asarray(cols, np.uint32)
    want = _lazy_value(cols, bits)
    # headroom: two extra digits always hold value < bound * S(L)
    ext = np.concatenate([cols, np.zeros(3, np.uint32)])[None, :]
    got = np.asarray(normalize_static(ext, bits, bound=bound))[0]
    assert got.max(initial=0) <= (1 << bits) - 1, "not normalized"
    assert _lazy_value(got, bits) == want, "value not preserved"


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:          # pragma: no cover - dev extra missing
    HAVE_HYP = False

if HAVE_HYP:
    SET = settings(max_examples=30, deadline=None)

    @given(st.integers(1, 64).flatmap(lambda n: st.lists(
        st.integers(0, 2**31 - 1), min_size=n, max_size=n)))
    @SET
    def test_normalize_static_invariant_16(cols):
        _check_normalize(cols, 16, bound=1 << 31)

    @given(st.integers(1, 64).flatmap(lambda n: st.lists(
        st.integers(0, 2**24 - 1), min_size=n, max_size=n)))
    @SET
    def test_normalize_static_invariant_7(cols):
        _check_normalize(cols, 7, bound=1 << 24)

    @pytest.mark.slow
    @given(st.just(None))
    @settings(max_examples=5, deadline=None)
    def test_normalize_static_invariant_wide(_):
        """Above-1024-bit lazy arrays (the fused-Karatsuba regime)."""
        n = int(RNG.integers(128, 256))           # 2048..4096 bits
        cols = RNG.integers(0, 1 << 31, n, dtype=np.int64).astype(np.uint32)
        _check_normalize(cols, 16, bound=1 << 31)

    @given(st.integers(1, 48).flatmap(lambda n: st.lists(
        st.integers(0, 2**31 - 1), min_size=n, max_size=n)))
    @SET
    def test_normalize_static_matches_while_loop(cols):
        """The kernel-safe static resolve agrees with the jnp while-loop
        formulation (core/mul.normalize_digits) digit-for-digit."""
        cols = np.asarray(cols, np.uint32)
        ext = np.concatenate([cols, np.zeros(3, np.uint32)])[None, :]
        stat = np.asarray(normalize_static(ext, 16, bound=1 << 31))
        loop = np.asarray(M.normalize_digits(ext, 16))
        np.testing.assert_array_equal(stat, loop)
else:                        # keep collection green without the dev extra
    def test_normalize_static_invariant_16():
        pytest.skip("hypothesis not installed")
