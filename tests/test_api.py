"""repro.api facade: one front door, one kwarg convention -- plus the
configure() override registry, the deprecated REPRO_* env aliases, and
the repo-standard "unknown ...; choose from ..." dispatcher errors."""
import random
import warnings

import numpy as np
import pytest

import repro
from repro import api
from repro import config as config_mod
from repro.core import div as DV
from repro.core import modular as MOD
from repro.core import mul as MUL

PY = random.Random(1234)


def _odd(bits):
    return PY.getrandbits(bits) | 1 | (1 << (bits - 1))


# ---------------------------------------------------------------------------
# package surface
# ---------------------------------------------------------------------------

def test_lazy_package_reexports():
    assert repro.mul is api.mul
    assert repro.configure is api.configure
    assert repro.api is api
    assert "mod_exp" in dir(repro)
    with pytest.raises(AttributeError):
        repro.no_such_name


def test_to_from_limbs_roundtrip():
    x = _odd(100)
    a = api.to_limbs(x, 128)
    assert a.shape == (4,) and a.dtype == np.uint32
    assert api.from_limbs(a) == x
    xs = [PY.getrandbits(90) for _ in range(3)]
    b = api.to_limbs(xs, 96)
    assert b.shape == (3, 3)
    assert api.from_limbs(b) == xs


# ---------------------------------------------------------------------------
# arithmetic front doors vs python-int oracles
# ---------------------------------------------------------------------------

def test_mul_matches_python_int():
    xs = [PY.getrandbits(120) for _ in range(2)]
    ys = [PY.getrandbits(120) for _ in range(2)]
    out = api.mul(api.to_limbs(xs, 128), api.to_limbs(ys, 128))
    assert api.from_limbs(out) == [x * y for x, y in zip(xs, ys)]


def test_divmod_matches_python_int():
    xs = [PY.getrandbits(120) for _ in range(2)]
    ys = [PY.getrandbits(70) | 1 for _ in range(2)]
    q, r = api.divmod(api.to_limbs(xs, 128), api.to_limbs(ys, 128))
    assert api.from_limbs(q) == [x // y for x, y in zip(xs, ys)]
    assert api.from_limbs(r) == [x % y for x, y in zip(xs, ys)]


def test_to_decimal():
    out = np.asarray(api.to_decimal(api.to_limbs(1234567, 64), 10))
    assert out.tolist() == [0, 0, 0, 1, 2, 3, 4, 5, 6, 7]


def test_mod_exp_int_args_single_lane():
    n = _odd(96)
    base, e = PY.randrange(2, n), 65537
    out = api.mod_exp(api.to_limbs(base, 96), e, n)
    assert api.from_limbs(np.asarray(out)) == pow(base, e, n)


def test_mod_exp_prebuilt_ctx_and_nbits_bucketing():
    n = _odd(80)
    base, e = PY.randrange(2, n), _odd(40)
    want = pow(base, e, n)
    # natural width vs padded-to-bucket width: same value out
    out_nat = api.mod_exp(api.to_limbs([base], 80), e, n)
    ctx = api.mod_setup(n, 128)
    out_pad = api.mod_exp(api.to_limbs([base], 80), e, ctx)
    assert api.from_limbs(np.asarray(out_nat)) == [want]
    assert api.from_limbs(np.asarray(out_pad))[0] == want


def test_mod_exp_even_modulus_routes_barrett():
    n = _odd(64) + 1                  # even: Montgomery impossible
    base, e = PY.randrange(2, n), 12345
    out = api.mod_exp(api.to_limbs([base], 64), e, n)
    assert api.from_limbs(np.asarray(out)) == [pow(base, e, n)]


def test_rsa_sign_verify_decrypt_roundtrip():
    key = api.generate_key(128, seed=7)
    msg = api.digest_int(b"facade", key.bits) % key.n
    ml = api.to_limbs([msg], key.bits)
    sig = api.rsa_sign(ml, key)
    assert api.from_limbs(np.asarray(sig)) == [pow(msg, key.d, key.n)]
    back = api.rsa_verify(sig, key)
    assert api.from_limbs(np.asarray(back)) == [msg]
    cipher = api.to_limbs([pow(msg, key.e, key.n)], key.bits)
    assert api.from_limbs(np.asarray(api.rsa_decrypt(cipher, key))) == [msg]
    assert api.from_limbs(np.asarray(
        api.rsa_decrypt(cipher, key, crt=False))) == [msg]


# ---------------------------------------------------------------------------
# configure(): scoping, precedence, validation
# ---------------------------------------------------------------------------

def test_configure_scoped_restores_previous():
    assert config_mod.get_override("mul_method") is None
    with api.configure(mul_method="schoolbook"):
        assert MUL.select_method(1024) == "schoolbook"
        with api.configure(mul_method="dot"):
            assert MUL.select_method(1024) == "dot"
        assert MUL.select_method(1024) == "schoolbook"
    assert config_mod.get_override("mul_method") is None


def test_configure_beats_env_alias(monkeypatch):
    monkeypatch.setenv("REPRO_MODEXP_BACKEND", "jnp")
    with api.configure(modexp_backend="reference"):
        assert MOD.select_modexp_backend(512, batch=64,
                                         ebits=512) == "reference"
    assert MOD.select_modexp_backend(512, batch=64, ebits=512) == "jnp"
    with api.configure(div_method="recip"):
        monkeypatch.setenv("REPRO_DIV_BACKEND", "schoolbook")
        assert DV.select_div_method(256, 256) == "recip"


def test_configure_none_clears_override():
    api.configure(div_method="recip")
    try:
        assert DV.select_div_method(4096, 4096) == "recip"
    finally:
        api.configure(div_method=None)
    assert config_mod.get_override("div_method") is None


@pytest.mark.parametrize("kwargs,fragment", [
    (dict(mul_method="bogus"), "multiply method"),
    (dict(div_method="bogus"), "division method"),
    (dict(modexp_backend="bogus"), "backend"),
    (dict(autotune="yes"), "autotune"),
])
def test_configure_validates(kwargs, fragment):
    with pytest.raises(ValueError) as e:
        api.configure(**kwargs)
    assert fragment in str(e.value)


def test_configure_lists_valid_options_in_error():
    with pytest.raises(ValueError) as e:
        api.configure(mul_method="bogus")
    for name in MUL.MUL_METHODS:
        assert name in str(e.value)


def test_configure_rejects_unknown_option():
    with pytest.raises(TypeError):
        api.configure(frobnicate=1)
    with pytest.raises(TypeError):
        config_mod.set_overrides({"frobnicate": 1})


def test_autotune_override_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert config_mod.autotune_enabled() is False
    with api.configure(autotune=True):
        assert config_mod.autotune_enabled() is True
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    assert config_mod.autotune_enabled() is True
    with api.configure(autotune=False):     # configure beats env
        assert config_mod.autotune_enabled() is False


# ---------------------------------------------------------------------------
# deprecated env aliases + dispatcher error-message contract
# ---------------------------------------------------------------------------

def test_env_alias_warns_deprecation_once(monkeypatch):
    monkeypatch.setenv("REPRO_DIV_BACKEND", "recip")
    config_mod._env_warned.discard("REPRO_DIV_BACKEND")
    with pytest.warns(DeprecationWarning, match="REPRO_DIV_BACKEND"):
        assert DV.select_div_method(256, 256) == "recip"
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        DV.select_div_method(256, 256)
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


@pytest.mark.parametrize("env_var,call", [
    ("REPRO_MUL_BACKEND", lambda: MUL.select_method(1024)),
    ("REPRO_DIV_BACKEND", lambda: DV.select_div_method(256, 256)),
    ("REPRO_MODEXP_BACKEND",
     lambda: MOD.select_modexp_backend(512, batch=64, ebits=512)),
])
def test_stale_env_value_is_identifiable(env_var, call, monkeypatch):
    monkeypatch.setenv(env_var, "bogus")
    with pytest.raises(ValueError) as e:
        call()
    assert env_var in str(e.value) and "bogus" in str(e.value)


def test_divmod_unknown_method_message():
    a = api.to_limbs([5], 64)
    with pytest.raises(ValueError) as e:
        api.divmod(a, a, method="bogus")
    msg = str(e.value)
    for name in DV.DIV_METHODS:
        assert name in msg
    assert "REPRO_DIV_BACKEND" in msg and "auto" in msg


def test_set_default_backend_unknown_message():
    with pytest.raises(ValueError) as e:
        MOD.set_default_backend("bogus")
    msg = str(e.value)
    for name in MOD.BACKENDS:
        assert name in msg


# ---------------------------------------------------------------------------
# to_limbs input validation (PR 9): uniform ValueError naming the argument
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("values,nbits,match", [
    (5, 0, "nbits must be a positive int"),
    (5, -32, "nbits must be a positive int"),
    (5, "64", "nbits must be a positive int"),
    (5, True, "nbits must be a positive int"),
    (-1, 64, r"values must be >= 0, got -1"),
    ([3, -7], 64, r"values\[1\] must be >= 0, got -7"),
    (1 << 64, 64, r"values needs 65 bits but nbits=64"),
    ([0, 1 << 40], 32, r"values\[1\] needs 41 bits but nbits=32"),
    (3.5, 64, "values must be an int or a sequence of ints"),
    (["7"], 64, r"values\[0\] must be an int, got str"),
    ([None], 64, r"values\[0\] must be an int, got NoneType"),
    (True, 64, "values must be an int"),
    ([False], 64, r"values\[0\] must be an int, got a bool"),
])
def test_to_limbs_rejects_bad_inputs(values, nbits, match):
    with pytest.raises(ValueError, match=match):
        api.to_limbs(values, nbits)


def test_to_limbs_accepts_numpy_ints_and_boundaries():
    # numpy integers coerce via __index__; declared-width boundary holds
    out = api.to_limbs([np.uint64(7), np.int32(5)], 64)
    assert api.from_limbs(out) == [7, 5]
    assert api.from_limbs(api.to_limbs((1 << 64) - 1, 64)) == (1 << 64) - 1
    # nbits is the declared width, not the rounded-up limb width
    with pytest.raises(ValueError, match="needs 34 bits but nbits=33"):
        api.to_limbs(1 << 33, 33)
    assert list(api.to_limbs(1 << 32, 33)) == [0, 1]
